"""Byzantine-robust ingest: the vote kernel vs its numpy oracle, robust
aggregation rules (majority / trimmed_mean / median), the quarantine
gate's reason taxonomy, the seeded attacker models, the extended ledger
(shipped == ingested + dropped + quarantined), and the defense telemetry
threaded through the simulation / async / fleet server paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.wire import (
    decode_update_leaves, encode_update, tree_from_records,
)
from repro.core.ternary import TernaryTensor
from repro.fed import FedConfig, FleetConfig, HierarchyConfig, run_fleet
from repro.fed.aggregator import (
    AGG_RULES, Aggregator, trimmed_mean, weighted_median,
)
from repro.fed.attackers import (
    ATTACKS, AttackConfig, attacker_ids, poison_blob,
)
from repro.fed.defense import REASONS, DefenseConfig, UpdateGate
from repro.fed.mp_server import client_update_blob, demo_params, params_hash
from repro.fed.simulation import resolve_rule
from repro.kernels.aggregate import LANES
from repro.kernels.vote import (
    majority_from_counts, packed_vote_counts, packed_vote_counts_ref,
)

SEED = 11


def _valid_codes(rng, shape):
    """Packed bytes whose four 2-bit fields are all valid codes {0,1,2}."""
    codes = rng.integers(0, 3, size=shape + (4,), dtype=np.uint8)
    return (codes[..., 0] | (codes[..., 1] << 2) | (codes[..., 2] << 4)
            | (codes[..., 3] << 6))


# --------------------------------------------------------------------------
# Vote kernel vs numpy oracle.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("c,rows", [(1, 32), (3, 32), (8, 64), (16, 96)])
def test_vote_kernel_matches_oracle(c, rows):
    rng = np.random.default_rng(c * 100 + rows)
    stacked = _valid_codes(rng, (c, rows, LANES))
    coeffs = rng.uniform(0.5, 3.0, size=(c,)).astype(np.float32)
    out = np.asarray(packed_vote_counts(
        jnp.asarray(stacked), jnp.asarray(coeffs), interpret=True
    ))
    np.testing.assert_allclose(
        out, packed_vote_counts_ref(stacked, coeffs), atol=1e-5
    )


def test_vote_zero_coeff_rows_contribute_nothing():
    """Padding clients carry coeff 0 — even all-garbage bytes vanish."""
    rng = np.random.default_rng(0)
    stacked = _valid_codes(rng, (4, 32, LANES))
    coeffs = np.array([1.5, 0.0, 0.0, 0.75], np.float32)
    zeroed = stacked.copy()
    zeroed[1:3] = 0xFF
    a = np.asarray(packed_vote_counts(jnp.asarray(stacked),
                                      jnp.asarray(coeffs), interpret=True))
    b = np.asarray(packed_vote_counts(jnp.asarray(zeroed),
                                      jnp.asarray(coeffs), interpret=True))
    np.testing.assert_array_equal(a, b)


def test_majority_from_counts_strict_plurality():
    #            -1 wins  +1 wins  tie±    zero wins  all-zero mass
    counts = np.array([[3.0, 1.0, 2.0, 1.0, 0.0],
                       [1.0, 3.0, 2.0, 1.0, 0.0]], np.float32)
    votes = majority_from_counts(counts, total_coeff=5.0)
    np.testing.assert_array_equal(votes, [-1, 1, 0, 0, 0])
    # the degenerate empty aggregation: everything resolves to "don't move"
    np.testing.assert_array_equal(
        majority_from_counts(np.zeros((2, 4), np.float32), 0.0), np.zeros(4)
    )


# --------------------------------------------------------------------------
# Robust rules end to end through the Aggregator.
# --------------------------------------------------------------------------


def test_majority_defeats_sign_flip_minority():
    """f < C/2 sign-flippers (by vote weight) cannot move any coordinate:
    the defended aggregate equals the honest-only majority EXACTLY."""
    params = demo_params(seed=1)
    honest = client_update_blob(params, 5, 3)
    atk = AttackConfig(kind="sign_flip", n_attackers=4, seed=0)
    flipped = poison_blob(honest, atk, client_id=0)
    # chunk_c=4 with 9 adds: full chunks + a partial flush both engage
    agg = Aggregator(chunk_c=4, rule="majority")
    ref = Aggregator(chunk_c=4, rule="majority")
    for _ in range(5):
        agg.add(honest, weight=2.0)
        ref.add(honest, weight=2.0)
    for _ in range(4):
        agg.add(flipped, weight=1.0)      # attacker mass 4 < honest mass 10
    assert params_hash(agg.finalize()) == params_hash(ref.finalize())


def test_majority_succumbs_to_flipping_majority():
    """The flip side of the guarantee: with f > C/2 the vote moves — the
    rule is a majority statistic, not magic."""
    params = demo_params(seed=1)
    honest = client_update_blob(params, 5, 3)
    flipped = poison_blob(
        honest, AttackConfig(kind="sign_flip", n_attackers=1), 0
    )
    agg = Aggregator(chunk_c=8, rule="majority")
    ref = Aggregator(chunk_c=8, rule="majority")
    agg.add(honest, weight=1.0)
    ref.add(honest, weight=1.0)
    for _ in range(3):
        agg.add(flipped, weight=1.0)
    assert params_hash(agg.finalize()) != params_hash(ref.finalize())


def test_median_rule_ignores_scale_blowup_minority():
    params = demo_params(seed=2)
    honest = client_update_blob(params, 1, 7)
    blown = poison_blob(
        honest, AttackConfig(kind="scale_blowup", n_attackers=1), 0
    )
    agg = Aggregator(chunk_c=8, rule="median")
    ref = Aggregator(chunk_c=8, rule="median")
    for _ in range(4):
        agg.add(honest, weight=1.0)
        ref.add(honest, weight=1.0)
    agg.add(blown, weight=1.0)
    assert params_hash(agg.finalize()) == params_hash(ref.finalize())


def test_weighted_median_and_trimmed_mean_primitives():
    stack = np.array([[1.0, -5.0], [2.0, 0.0], [100.0, 5.0]], np.float32)
    w = np.ones(3, np.float32)
    np.testing.assert_array_equal(weighted_median(stack, w), [2.0, 0.0])
    # one outlier trimmed per side: the middle row survives alone
    np.testing.assert_allclose(
        trimmed_mean(stack, w, trim_frac=0.34), [2.0, 0.0]
    )
    # weight mass moves the median: the heavy first row wins coordinate 0
    np.testing.assert_array_equal(
        weighted_median(stack, np.array([5.0, 1.0, 1.0], np.float32)),
        [1.0, -5.0],
    )


def test_rule_validation():
    with pytest.raises(ValueError, match="rule"):
        Aggregator(rule="geometric_median")
    with pytest.raises(ValueError, match="trim_frac"):
        Aggregator(trim_frac=0.5)
    assert set(AGG_RULES) == {"mean", "majority", "trimmed_mean", "median"}
    with pytest.raises(ValueError, match="fused_aggregation"):
        resolve_rule(FedConfig(
            fused_aggregation=False,
            defense=DefenseConfig(enabled=True, rule="majority"),
        ))
    # defense off → the legacy mean regardless of the configured rule
    assert resolve_rule(FedConfig())[0] == "mean"
    assert resolve_rule(FedConfig(
        defense=DefenseConfig(enabled=False, rule="median")
    ))[0] == "mean"


# --------------------------------------------------------------------------
# The quarantine gate: every reason is reachable, honest traffic is not.
# --------------------------------------------------------------------------


def _gate(params, **kw):
    kw.setdefault("enabled", True)
    return UpdateGate(DefenseConfig(**kw), params)


def test_gate_passes_honest_and_is_bit_exact_with_mean():
    params = demo_params(seed=3)
    blobs = [client_update_blob(params, cid, SEED) for cid in range(4)]
    gate = _gate(params)
    on, off = Aggregator(chunk_c=4), Aggregator(chunk_c=4)
    for cid, b in enumerate(blobs):
        assert gate.check(b).ok
        on.add(b, weight=1.0 + cid)
        off.add(b, weight=1.0 + cid)
    # defense-on over honest clients never touches a byte: same aggregate
    assert params_hash(on.finalize()) == params_hash(off.finalize())
    t = gate.telemetry()
    assert t["passed_updates"] == 4 and t["quarantined_updates"] == 0
    assert t["passed_bytes"] == sum(len(b) for b in blobs)
    assert t["reasons"] == {}


def test_gate_reason_malformed():
    gate = _gate(demo_params())
    v = gate.check(b"\x00garbage that never framed")
    assert not v.ok and v.reason == "malformed"
    assert gate.reasons["malformed"] == 1


def test_gate_reason_structure():
    params = demo_params(seed=4)
    gate = _gate(params)
    # a perfectly valid update for a DIFFERENT model
    alien = client_update_blob(demo_params(seed=4, d=32), 0, SEED)
    v = gate.check(alien)
    assert not v.ok and v.reason == "structure"


def test_gate_nonfinite_checks_catch_every_nan_poison():
    """nan_poison recall is 1.0 from the very first round — finiteness
    needs no history. Which finiteness reason fires depends on whether a
    poisoned raw-float leaf or a poisoned ternary scale is met first."""
    params = demo_params(seed=5)
    atk = AttackConfig(kind="nan_poison", n_attackers=3, seed=SEED)
    gate = _gate(params)
    for cid in range(3):
        blob = poison_blob(client_update_blob(params, cid, SEED), atk, cid)
        v = gate.check(blob)
        assert not v.ok
        assert v.reason in ("scale_nonfinite", "payload_nonfinite")
    assert gate.quarantined_updates == 3


def test_gate_reason_scale_nonfinite_on_pure_ternary_tree():
    """With no raw-float leaves in the update, the ternary scale check is
    the one that fires."""
    params = demo_params(seed=5)
    blob = client_update_blob(params, 0, SEED)
    poisoned = []
    for path, leaf in decode_update_leaves(blob, zero_copy=True):
        if isinstance(leaf, TernaryTensor):
            leaf = TernaryTensor(packed=np.asarray(leaf.packed),
                                 w_q=np.full_like(np.asarray(leaf.w_q),
                                                  np.inf),
                                 shape=tuple(leaf.shape), dtype=leaf.dtype)
        poisoned.append((path, leaf))
    v = _gate(params).check(encode_update(tree_from_records(poisoned)))
    assert not v.ok and v.reason == "scale_nonfinite"


def test_gate_reason_scale_bound_needs_warm_history():
    params = demo_params(seed=6)
    honest = [client_update_blob(params, cid, SEED) for cid in range(3)]
    blown = poison_blob(
        honest[0], AttackConfig(kind="scale_blowup", n_attackers=1), 0
    )
    gate = _gate(params, min_history=2, scale_bound=10.0)
    assert gate.check(blown).ok          # cold start: observe-only by design
    for b in honest[1:]:
        assert gate.check(b).ok
    v = gate.check(blown)                # history warm: the bound is live
    assert not v.ok and v.reason == "scale_bound"


def test_gate_reason_code_plane():
    params = demo_params(seed=7)
    blob = client_update_blob(params, 0, SEED)
    pairs = decode_update_leaves(blob, zero_copy=True)
    poisoned = []
    hit = False
    for path, leaf in pairs:
        if isinstance(leaf, TernaryTensor) and not hit:
            packed = np.array(leaf.packed, dtype=np.uint8, copy=True)
            packed.reshape(-1)[0] = 0xFF        # four reserved code-3 fields
            leaf = TernaryTensor(packed=packed, w_q=np.asarray(leaf.w_q),
                                 shape=tuple(leaf.shape), dtype=leaf.dtype)
            hit = True
        poisoned.append((path, leaf))
    assert hit
    v = _gate(params).check(encode_update(tree_from_records(poisoned)))
    assert not v.ok and v.reason == "code_plane"


def test_gate_reason_payload_nonfinite():
    params = {"b": np.zeros(8, np.float32)}
    gate = _gate(params)
    assert gate.check(encode_update({"b": np.ones(8, np.float32)})).ok
    bad = np.ones(8, np.float32)
    bad[3] = np.nan
    v = gate.check(encode_update({"b": bad}))
    assert not v.ok and v.reason == "payload_nonfinite"
    assert set(gate.reasons) <= set(REASONS)


def test_defense_config_validation():
    with pytest.raises(ValueError, match="rule"):
        DefenseConfig(rule="krum")
    with pytest.raises(ValueError, match="scale_bound"):
        DefenseConfig(scale_bound=1.0)
    with pytest.raises(ValueError, match="min_history"):
        DefenseConfig(min_history=0)
    with pytest.raises(ValueError, match="trim_frac"):
        DefenseConfig(trim_frac=0.5)


# --------------------------------------------------------------------------
# Attacker models: seeded, wire-valid, reproducible.
# --------------------------------------------------------------------------


def test_attacker_blobs_stay_wire_valid():
    params = demo_params(seed=8)
    honest = client_update_blob(params, 0, SEED)
    honest_paths = [p for p, _ in decode_update_leaves(honest)]
    for kind in ATTACKS:
        atk = AttackConfig(kind=kind, n_attackers=1, seed=SEED)
        blob = poison_blob(honest, atk, client_id=0)
        pairs = decode_update_leaves(blob)       # framing + CRC still hold
        assert [p for p, _ in pairs] == honest_paths
        assert blob != honest


def test_sign_flip_is_an_involution():
    """Flip twice ⇒ byte-identical to the honest encoding — the reserved
    code never appears and the re-encode is deterministic."""
    params = demo_params(seed=9)
    honest = client_update_blob(params, 2, SEED)
    atk = AttackConfig(kind="sign_flip", n_attackers=1, seed=SEED)
    once = poison_blob(honest, atk, client_id=2)
    assert once != honest
    assert poison_blob(once, atk, client_id=2) == honest


def test_collude_cohort_ships_identical_bytes():
    params = demo_params(seed=9)
    honest = client_update_blob(params, 0, SEED)
    atk = AttackConfig(kind="collude", n_attackers=2, seed=SEED)
    a = poison_blob(honest, atk, client_id=0, round_idx=1)
    b = poison_blob(honest, atk, client_id=17, round_idx=1)
    assert a == b                       # keyed on the round, not the client
    # gaussian attackers are independent: same inputs, different clients
    g = AttackConfig(kind="gaussian", n_attackers=2, seed=SEED)
    assert poison_blob(honest, g, 0) != poison_blob(honest, g, 17)


def test_attacker_ids_seeded_and_bounded():
    cfg = AttackConfig(kind="sign_flip", n_attackers=5, seed=3)
    ids = attacker_ids(cfg, 16)
    assert ids == attacker_ids(cfg, 16)
    assert len(ids) == 5 and all(0 <= i < 16 for i in ids)
    assert len(attacker_ids(cfg, 3)) == 3          # clamped to the cohort
    assert attacker_ids(AttackConfig(n_attackers=0), 16) == frozenset()


def test_attack_config_validation():
    with pytest.raises(ValueError, match="kind"):
        AttackConfig(kind="rootkit")
    with pytest.raises(ValueError, match="n_attackers"):
        AttackConfig(n_attackers=-1)
    with pytest.raises(ValueError, match="blowup"):
        AttackConfig(blowup=1.0)


# --------------------------------------------------------------------------
# The extended ledger on one Aggregator (property: note_* interleaving
# over bucket boundaries never perturbs the aggregate).
# --------------------------------------------------------------------------


def test_aggregator_ledger_interleaved_over_bucket_boundaries():
    params = demo_params(seed=10)
    blobs = [client_update_blob(params, cid, SEED) for cid in range(3)]
    rng = np.random.default_rng(42)
    agg = Aggregator(chunk_c=4)
    ref = Aggregator(chunk_c=4)
    dropped = quarantined = 0
    dropped_b = quarantined_b = 0
    adds = []
    # 23 adds: crosses the partial buckets 1→2→4 and several full chunks,
    # with drop/quarantine notes landing between partial adds
    for step in range(40):
        op = int(rng.integers(4))
        blob = blobs[step % 3]
        if op <= 1 and len(adds) < 23:
            w = 1.0 + step % 5
            agg.add(blob, weight=w)
            adds.append((blob, w))
        elif op == 2:
            agg.note_dropped(len(blob))
            dropped += 1
            dropped_b += len(blob)
        else:
            agg.note_quarantined(len(blob))
            quarantined += 1
            quarantined_b += len(blob)
        assert agg.dropped_updates == dropped
        assert agg.dropped_bytes == dropped_b
        assert agg.quarantined_updates == quarantined
        assert agg.quarantined_bytes == quarantined_b
    assert dropped and quarantined       # the interleave really happened
    for blob, w in adds:
        ref.add(blob, weight=w)
    assert agg.n_clients == len(adds)
    out = agg.finalize(reset=True)
    assert params_hash(out) == params_hash(ref.finalize())
    # reset clears the aggregation, NOT the run-level waste ledger
    assert agg.n_clients == 0
    assert agg.dropped_updates == dropped
    assert agg.quarantined_updates == quarantined
    agg.note_quarantined(100)
    assert agg.quarantined_bytes == quarantined_b + 100
    # shipped == ingested + dropped + quarantined, in bytes
    shipped = sum(len(b) for b, _ in adds) + dropped_b + quarantined_b + 100
    ingested = sum(len(b) for b, _ in adds)
    assert shipped == ingested + agg.dropped_bytes + agg.quarantined_bytes


# --------------------------------------------------------------------------
# Server paths: the defense telemetry + extended ledger in fleet
# sync/async/tier, then the training simulation paths (sync/async).
# The socket path's ledger lives in test_mp_server.py.
# --------------------------------------------------------------------------


def _fleet_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": {"w": rng.standard_normal((48, 16)).astype(np.float32),
                  "b": np.zeros(16, np.float32)},
    }


def _fleet_cfg(**kw):
    base = dict(n_clients=400, rounds=2, participation=0.2,
                attack=AttackConfig(kind="nan_poison", n_attackers=120,
                                    seed=5),
                defense=DefenseConfig(enabled=True))
    base.update(kw)
    return FedConfig(**base)


def test_fleet_sync_quarantines_and_balances_ledger():
    res = run_fleet(_fleet_params(), _fleet_cfg())
    d = res.telemetry["defense"]
    assert d["enabled"] and d["ledger_balanced"]
    assert d["quarantined_updates"] > 0
    assert sum(d["reasons"].values()) == d["quarantined_updates"]
    assert set(d["reasons"]) <= {"scale_nonfinite", "payload_nonfinite"}
    assert res.final_update is not None          # survivors still aggregate


def test_fleet_tier_quarantines_and_balances_ledger():
    res = run_fleet(_fleet_params(),
                    _fleet_cfg(hierarchy=HierarchyConfig(n_edges=4)))
    d = res.telemetry["defense"]
    assert d["ledger_balanced"] and d["quarantined_updates"] > 0
    hier = res.telemetry["hierarchy"]
    assert hier["quarantined_updates"] > 0
    assert hier["ledger_balanced"]


def test_fleet_async_quarantines_and_balances_ledger():
    res = run_fleet(_fleet_params(),
                    _fleet_cfg(mode="async", rounds=3, buffer_k=8))
    d = res.telemetry["defense"]
    assert d["ledger_balanced"] and d["quarantined_updates"] > 0


def test_fleet_defense_off_matches_legacy_bit_for_bit():
    """attack=None + defense=None is the pre-defense fleet: same rounds,
    same bytes, same final update as a config that never mentions them."""
    legacy = run_fleet(_fleet_params(), FedConfig(
        n_clients=400, rounds=2, participation=0.2))
    off = run_fleet(_fleet_params(), FedConfig(
        n_clients=400, rounds=2, participation=0.2,
        defense=DefenseConfig(enabled=False)))
    assert legacy.upload_bytes == off.upload_bytes
    assert legacy.round_times == off.round_times
    assert params_hash(legacy.final_update) == params_hash(off.final_update)
    assert "defense" not in off.telemetry


def test_fleet_majority_rule_survives_collude_minority():
    res = run_fleet(_fleet_params(), _fleet_cfg(
        attack=AttackConfig(kind="collude", n_attackers=100, seed=5),
        defense=DefenseConfig(enabled=True, rule="majority"),
    ), FleetConfig(compat=False))
    d = res.telemetry["defense"]
    # collude is gate-invisible (flips are plausible payloads) ...
    assert d["quarantined_updates"] == 0 and d["ledger_balanced"]
    # ... but the vote still produced a finite aggregate
    leaves = jax.tree_util.tree_leaves(res.final_update)
    assert all(np.isfinite(np.asarray(leaf)).all() for leaf in leaves)


# --------------------------------------------------------------------------
# The training simulation paths.
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_task():
    from repro.data import partition_iid, synthetic_classification
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 600, 10, 784, noise=3.0, n_test=100
    )
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        return float(acc), 0.0

    return clients, params, eval_fn, mlp_mnist


def _sim_cfg(**kw):
    base = dict(algorithm="tfedavg", participation=1.0, local_epochs=1,
                batch_size=64, rounds=2)
    base.update(kw)
    return FedConfig(**base)


def test_sim_sync_quarantines_attackers_and_balances_ledger(sim_task):
    from repro.fed import run_federated
    from repro.optim import adam

    clients, params, eval_fn, apply_fn = sim_task
    cfg = _sim_cfg(attack=AttackConfig(kind="nan_poison", n_attackers=1,
                                       seed=2),
                   defense=DefenseConfig(enabled=True))
    res = run_federated(apply_fn, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=2)
    d = res.telemetry["defense"]
    assert d["enabled"] and d["ledger_balanced"]
    # one attacker per round, participation 1.0, lossless default channel:
    # its upload arrives and is quarantined every round
    assert d["quarantined_updates"] == cfg.rounds
    assert d["passed_updates"] == cfg.rounds * (len(clients) - 1)
    assert res.rounds_run == cfg.rounds


def test_sim_sync_defense_on_honest_matches_defense_off(sim_task):
    """The gate never mutates accepted payloads and draws no randomness:
    an all-honest defended run replays the undefended run exactly."""
    from repro.fed import run_federated
    from repro.optim import adam

    clients, params, eval_fn, apply_fn = sim_task
    off = run_federated(apply_fn, params, clients, _sim_cfg(), adam(1e-3),
                        eval_fn, eval_every=1)
    on = run_federated(apply_fn, params, clients,
                       _sim_cfg(defense=DefenseConfig(enabled=True)),
                       adam(1e-3), eval_fn, eval_every=1)
    assert on.accuracy == off.accuracy
    assert on.upload_bytes == off.upload_bytes
    assert on.round_times == off.round_times
    d = on.telemetry["defense"]
    assert d["quarantined_updates"] == 0 and d["ledger_balanced"]


def test_sim_async_gates_before_staleness_and_balances_ledger(sim_task):
    from repro.fed import run_federated
    from repro.optim import adam

    clients, params, eval_fn, apply_fn = sim_task
    cfg = _sim_cfg(mode="async", rounds=3, buffer_k=2,
                   attack=AttackConfig(kind="nan_poison", n_attackers=1,
                                       seed=2),
                   defense=DefenseConfig(enabled=True))
    res = run_federated(apply_fn, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=3)
    d = res.telemetry["defense"]
    assert d["ledger_balanced"]
    assert d["quarantined_updates"] > 0
    assert res.rounds_run == 3
