"""Property-based tests (hypothesis) for the system's invariants:

  - pack/unpack 2-bit codec is an exact bijection on ternary arrays,
  - FTTQ is unbiased under symmetric weights (paper Prop. 4.2),
  - the trained factor init is the L2 optimum (Prop. 4.1 / eq. 20),
  - server aggregation is a convex combination (weights sum to 1),
  - ternary compression error is bounded by the quantization radius,
  - error feedback makes repeated compression of a constant signal exact
    in cumulative mean.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CodecSpec, FTTQConfig, compress_pytree, decompress_pytree,
    pack2bit, unpack2bit,
)
from repro.core import fttq as F

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    n=st.integers(min_value=1, max_value=4096),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    it = rng.integers(-1, 2, size=(n,)).astype(np.int8)
    packed = pack2bit(jnp.asarray(it))
    assert packed.size == (n + 3) // 4
    out = unpack2bit(packed, n, jnp.int8)
    np.testing.assert_array_equal(np.asarray(out), it)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(**SETTINGS)
def test_fttq_unbiased_on_uniform(seed):
    """Prop 4.2: E[FTTQ(θ)] = E[θ] = 0 for θ ~ U(-1, 1)."""
    key = jax.random.PRNGKey(seed)
    theta = jax.random.uniform(key, (512, 256), minval=-1.0, maxval=1.0)
    cfg = FTTQConfig()
    wq = F.init_wq(theta, cfg)
    out = F.fttq_quantize(theta, wq, cfg.t_k)
    # quantizer output mean ≈ input mean ≈ 0 (tolerance ~ 3·σ/√n of mean)
    assert abs(float(jnp.mean(out))) < 0.01
    assert abs(float(jnp.mean(theta))) < 0.01


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    rows=st.integers(min_value=4, max_value=64),
    cols=st.integers(min_value=4, max_value=64),
)
@settings(**SETTINGS)
def test_wq_l2_optimality(seed, rows, cols):
    theta = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    cfg = FTTQConfig()
    wq = float(F.init_wq(theta, cfg))
    ts = F.scale_layer(theta)
    it = np.asarray(F.ternarize(ts, F.fttq_threshold(ts, cfg.t_k)))
    if not it.any():
        return  # degenerate: everything below threshold
    th = np.asarray(theta)
    for w in (wq * 0.9, wq * 1.1):
        assert np.sum((th - wq * it) ** 2) <= np.sum((th - w * it) ** 2) + 1e-4


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_clients=st.integers(min_value=1, max_value=6),
)
@settings(**SETTINGS)
def test_aggregation_convex_combination(seed, n_clients):
    """Weighted FedAvg: aggregate of identical payloads is the payload; the
    aggregate lies in the convex hull per coordinate."""
    from repro.core.tfedavg import TernaryUpdate, server_aggregate

    rng = np.random.default_rng(seed)
    payloads = [
        {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
        for _ in range(n_clients)
    ]
    ups = [
        TernaryUpdate(payload=p, n_samples=int(rng.integers(1, 100)), client_id=i)
        for i, p in enumerate(payloads)
    ]
    agg = server_aggregate(ups)
    stacked = np.stack([np.asarray(p["w"]) for p in payloads])
    assert np.all(np.asarray(agg["w"]) <= stacked.max(0) + 1e-5)
    assert np.all(np.asarray(agg["w"]) >= stacked.min(0) - 1e-5)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(**SETTINGS)
def test_compression_error_bounded(seed):
    """|θ − dequant(compress(θ))|∞ ≤ max|θ| + w_q (coarse but guaranteed)."""
    key = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(key, (64, 32))}
    spec = CodecSpec(kind="ternary")
    wire, _ = compress_pytree(tree, spec)
    rec = decompress_pytree(wire, spec)
    err = np.abs(np.asarray(tree["w"]) - np.asarray(rec["w"]))
    bound = float(jnp.max(jnp.abs(tree["w"])))
    assert err.max() <= bound + 1e-4


@given(
    n=st.integers(min_value=1, max_value=2048),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(**SETTINGS)
def test_wire_update_roundtrip(n, seed):
    """encode_update/decode_update is the identity on mixed ternary+raw
    payloads of any leaf size (including non-multiples of 4)."""
    from repro.comm import decode_update, encode_update
    from repro.core.ternary import encode_ternary

    rng = np.random.default_rng(seed)
    i_t = jnp.asarray(rng.integers(-1, 2, size=(n,)).astype(np.int8))
    raw = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    tree = {"w": encode_ternary(i_t, jnp.float32(rng.normal())), "b": raw}
    back = decode_update(encode_update(tree))
    np.testing.assert_array_equal(np.asarray(back["w"].ternary()), np.asarray(i_t))
    np.testing.assert_array_equal(np.asarray(back["w"].w_q), np.asarray(tree["w"].w_q))
    np.testing.assert_array_equal(np.asarray(back["b"]), np.asarray(raw))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_error_feedback_reduces_bias(seed):
    """Repeatedly compressing the SAME gradient with error feedback: the
    time-average of the decompressed stream converges to the true value
    (residual carries what quantization dropped)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (32, 16))
    spec = CodecSpec(kind="ternary", error_feedback=True)
    res = None
    acc = np.zeros_like(np.asarray(g))
    n = 12
    for _ in range(n):
        wire, res = compress_pytree({"w": g}, spec, residual=res)
        acc += np.asarray(decompress_pytree(wire, spec)["w"])
    mean_stream = acc / n
    base_err = np.abs(np.asarray(g)).mean()
    ef_err = np.abs(mean_stream - np.asarray(g)).mean()
    assert ef_err < 0.35 * base_err
