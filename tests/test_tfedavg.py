"""Integration tests for the T-FedAvg protocol (paper Algorithm 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FTTQConfig
from repro.core.tfedavg import (
    TernaryUpdate, client_update_payload, fedavg_round_bytes,
    server_aggregate, server_requantize, tfedavg_round_bytes,
)
from repro.core.ternary import TernaryTensor
from repro.core import fttq as F
from repro.data import partition_iid, partition_noniid, synthetic_classification
from repro.fed import FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam

CFG = FTTQConfig()


@pytest.fixture(scope="module")
def dataset():
    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 1500, 10, 784, noise=3.0, n_test=400
    )
    return x, y, xt, yt


def _eval_fn(xt, yt):
    xt = jnp.asarray(xt); yt = jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(jnp.take_along_axis(logp, yt[:, None], -1))
        return float(acc), float(loss)

    return eval_fn


def test_payload_roundtrip():
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    wq = F.init_wq_tree(params, CFG)
    payload = client_update_payload(params, wq, CFG)
    assert isinstance(payload["fc0"]["w"], TernaryTensor)
    assert payload["fc2"]["bias"].shape == (10,)  # output bias ships fp32
    deq = payload["fc0"]["w"].dequantize()
    assert deq.shape == params["fc0"]["w"].shape
    # reconstruction correlates strongly with the original
    a = np.asarray(deq).ravel(); b = np.asarray(params["fc0"]["w"]).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.7


def test_aggregation_weighted_mean():
    p1 = {"w": jnp.ones((4, 4))}
    p2 = {"w": jnp.zeros((4, 4))}
    agg = server_aggregate([
        TernaryUpdate(payload=p1, n_samples=300),
        TernaryUpdate(payload=p2, n_samples=100),
    ])
    np.testing.assert_allclose(np.asarray(agg["w"]), 0.75)


def test_server_requantize_is_ternary_wire():
    params = init_mlp_mnist(jax.random.PRNGKey(2))
    wire = server_requantize(params, CFG)
    t = wire["fc1"]["w"]
    assert isinstance(t, TernaryTensor)
    codes = np.asarray(t.ternary())
    assert set(np.unique(codes)).issubset({-1, 0, 1})


def test_round_bytes_16x(dataset):
    """Paper Table IV: T-FedAvg ≈ 1/16 of FedAvg per round."""
    params = init_mlp_mnist(jax.random.PRNGKey(3))
    fed = fedavg_round_bytes(params, 10)
    tfed = tfedavg_round_bytes(params, 10, CFG)
    ratio = fed["upload"] / tfed["upload"]
    assert 10 < ratio < 16.5  # biases stay fp32 ⇒ slightly under 16×


def test_protocol_end_to_end_learns(dataset):
    x, y, xt, yt = dataset
    clients = partition_iid(x, y, 5)
    params = init_mlp_mnist(jax.random.PRNGKey(4))
    cfg = FedConfig(algorithm="tfedavg", participation=1.0, local_epochs=3,
                    batch_size=32, rounds=12, fttq=CFG)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                        _eval_fn(xt, yt), eval_every=12)
    assert res.accuracy[-1] > 0.5
    assert res.upload_bytes < res.rounds_run * 5 * 120_000  # ≪ fp32 (≈0.5MB/client)


def test_straggler_mitigation_never_loses_round(dataset):
    """Stragglers are emergent: a tight round deadline over a slow,
    heterogeneous channel drops clients — yet no round is ever lost."""
    from repro.comm import ChannelConfig

    x, y, xt, yt = dataset
    clients = partition_iid(x, y, 6)
    params = init_mlp_mnist(jax.random.PRNGKey(5))
    chan = ChannelConfig(mean_bandwidth_bytes_s=2e5, bandwidth_sigma=1.0,
                         deadline_s=0.25, compute_speed_sigma=1.0)
    cfg = FedConfig(algorithm="tfedavg", participation=1.0, local_epochs=1,
                    batch_size=32, rounds=3, channel=chan)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        _eval_fn(xt, yt), eval_every=3)
    assert res.rounds_run == 3
    assert all(p >= 1 for p in res.participants_per_round)
    assert sum(res.dropped_per_round) > 0      # the deadline actually bit
    # any round that dropped someone cost the server the full deadline (or
    # longer, if the all-dropped fallback waited for the fastest client).
    assert all(
        t >= 0.25 - 1e-9
        for t, d in zip(res.round_times, res.dropped_per_round) if d > 0
    )


def test_noniid_partition_properties(dataset):
    x, y, _, _ = dataset
    clients = partition_noniid(x, y, 5, n_classes_per_client=2)
    total = sum(len(c) for c in clients)
    assert total == len(y)
    for c in clients:
        if len(c):
            assert len(np.unique(c.y)) <= 2
