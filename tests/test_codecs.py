"""Codec registry tests: per-codec round trips through the v2 wire, the
per-direction (upstream/downstream) split in the federated servers, and
the measured-bytes contract for the new codecs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import decode_update, encode_update, update_nbytes
from repro.core import (
    CodecSpec,
    CompressionSpec,
    DowncastTensor,
    TopKTensor,
    available_codecs,
    compress_pytree,
    decompress_pytree,
    get_codec,
)
from repro.core.ternary import TernaryTensor, encode_ternary


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "layer": {
            "w": jax.random.normal(k1, (48, 24)),          # quantizable
            "bias": jax.random.normal(k2, (24,)) * 0.1,    # residual stream
        },
        "norm_scale": jnp.arange(8.0) / 8.0,               # residual stream
    }


def test_registry_ships_the_four_codec_families():
    assert {"none", "ternary", "fp16", "bf16", "topk"} <= set(available_codecs())
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("gzip")
    with pytest.raises(ValueError, match="unknown compression"):
        CodecSpec(kind="gzip")
    with pytest.raises(ValueError, match="topk_fraction"):
        CodecSpec(kind="topk", topk_fraction=0.0)


# --------------------------------------------------------------------------
# Wire round trips (acceptance: fp16 and top-k bit-exact through v2).
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["fp16", "bf16"])
def test_downcast_roundtrip_bitexact(kind):
    tree = _tree(1)
    wire, _ = compress_pytree(tree, CodecSpec(kind=kind, residual=kind))
    back = decode_update(encode_update(wire))
    for key in (("layer", "w"), ("layer", "bias")):
        a, b = wire[key[0]][key[1]], back[key[0]][key[1]]
        assert isinstance(a, DowncastTensor) and isinstance(b, DowncastTensor)
        assert a.orig_dtype == b.orig_dtype == "float32"
        np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))
    # decode restores the logical dtype and halves the wire bytes
    dec = decompress_pytree(back)
    assert dec["layer"]["w"].dtype == jnp.float32
    assert update_nbytes(wire) < 0.6 * update_nbytes(tree)


def test_topk_roundtrip_bitexact_and_sparse_decode():
    tree = _tree(2)
    spec = CodecSpec(kind="topk", residual="topk", topk_fraction=0.125)
    wire, _ = compress_pytree(tree, spec)
    t = wire["layer"]["w"]
    assert isinstance(t, TopKTensor)
    assert t.indices.size == int(np.ceil(0.125 * 48 * 24))
    back = decode_update(encode_update(wire))
    np.testing.assert_array_equal(
        np.asarray(t.indices), np.asarray(back["layer"]["w"].indices))
    np.testing.assert_array_equal(
        np.asarray(t.values), np.asarray(back["layer"]["w"].values))
    # decode: kept positions exact, dropped positions exactly zero
    dec = decompress_pytree(back)["layer"]["w"]
    orig = np.asarray(tree["layer"]["w"]).reshape(-1)
    idx = np.asarray(t.indices)
    np.testing.assert_array_equal(np.asarray(dec).reshape(-1)[idx], orig[idx])
    mask = np.ones(orig.size, bool)
    mask[idx] = False
    assert np.all(np.asarray(dec).reshape(-1)[mask] == 0.0)
    # the kept set is the top-|value| set
    thresh = np.abs(orig[idx]).min()
    assert np.all(np.abs(orig[mask]) <= thresh + 1e-7)


def test_mixed_spec_quantizable_vs_residual_split():
    """kind applies to weight-like leaves, residual to the bias/norm rest."""
    tree = _tree(3)
    wire, _ = compress_pytree(tree, CodecSpec(kind="ternary", residual="fp16"))
    assert isinstance(wire["layer"]["w"], TernaryTensor)
    assert isinstance(wire["layer"]["bias"], DowncastTensor)
    assert isinstance(wire["norm_scale"], DowncastTensor)
    dec = decompress_pytree(decode_update(encode_update(wire)))
    np.testing.assert_allclose(
        np.asarray(dec["layer"]["bias"]), np.asarray(tree["layer"]["bias"]),
        rtol=2e-3, atol=2e-4,
    )


def test_residual_codec_never_touches_non_float_leaves():
    """Optimizer steps, RNG keys and masks ship raw even under lossy
    residual codecs — a float codec would corrupt them."""
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
        "step": jnp.asarray(100_000, jnp.int32),
        "rng": jnp.asarray([4059202431, 2870008242], jnp.uint32),
        "mask": jnp.asarray([True, False, True]),
    }
    for residual in ("fp16", "bf16", "topk"):
        wire, _ = compress_pytree(tree, CodecSpec(kind="ternary", residual=residual))
        dec = decompress_pytree(decode_update(encode_update(wire)))
        assert int(dec["step"]) == 100_000, residual
        np.testing.assert_array_equal(np.asarray(dec["rng"]), np.asarray(tree["rng"]))
        np.testing.assert_array_equal(np.asarray(dec["mask"]), np.asarray(tree["mask"]))


def test_register_codec_rejects_duplicates_and_unframed_leaves():
    import repro.core.compression as comp_mod
    from repro.comm import WireError, encode_update as enc

    class FakeCodec:
        name = "fp16"
        wire_kind = comp_mod.KIND_DOWNCAST
        leaf_type = DowncastTensor

        def encode_leaf(self, leaf, spec):
            return leaf

        def decode_leaf(self, leaf):
            return leaf

    with pytest.raises(ValueError, match="already registered"):
        comp_mod.register_codec(FakeCodec())

    # a codec registered without a wire record must fail loudly at encode,
    # not silently serialize its children as containers
    @jax.tree_util.register_pytree_node_class
    class OrphanLeaf:
        def __init__(self, data):
            self.data = data

        def tree_flatten(self):
            return (self.data,), None

        @classmethod
        def tree_unflatten(cls, aux, children):
            return cls(children[0])

    class OrphanCodec:
        name = "orphan-test"
        wire_kind = 200
        leaf_type = OrphanLeaf

        def encode_leaf(self, leaf, spec):
            return OrphanLeaf(leaf)

        def decode_leaf(self, leaf):
            return leaf.data

    comp_mod.register_codec(OrphanCodec())
    try:
        with pytest.raises(WireError, match="no .*record kind"):
            enc({"x": OrphanLeaf(jnp.ones(3))})
    finally:
        del comp_mod._CODECS["orphan-test"]


def test_compress_finishes_partially_compressed_tree():
    """A QAT payload (TernaryTensor weights already in place) passes through
    untouched; only the raw residual leaves get the residual codec."""
    i_t = jnp.asarray(np.random.default_rng(0).integers(-1, 2, (16, 8)), jnp.int8)
    payload = {"w": encode_ternary(i_t, jnp.float32(0.5)), "b": jnp.arange(4.0)}
    wire, _ = compress_pytree(payload, CodecSpec(kind="ternary", residual="bf16"))
    assert wire["w"] is payload["w"]
    assert isinstance(wire["b"], DowncastTensor)


def test_error_feedback_generic_over_codecs():
    """EF makes the cumulative mean of repeated topk compressions exact."""
    g = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
    spec = CodecSpec(kind="topk", residual="none", topk_fraction=0.2,
                     error_feedback=True)
    res = None
    acc = np.zeros((32, 16), np.float32)
    n = 15
    for _ in range(n):
        wire, res = compress_pytree({"w": g}, spec, residual=res)
        acc += np.asarray(decompress_pytree(wire)["w"])
    ef_err = np.abs(acc / n - np.asarray(g)).mean()
    base_err = np.abs(np.asarray(g)).mean() * 0.8  # plain topk drops 80%
    assert ef_err < 0.35 * base_err


# --------------------------------------------------------------------------
# Satellite: TernaryTensor.nbytes_wire derives scale bytes from w_q.
# --------------------------------------------------------------------------


def test_nbytes_wire_derives_scale_bytes_from_wq_dtype():
    i_t = jnp.asarray(np.random.default_rng(1).integers(-1, 2, (4, 8, 8)), jnp.int8)
    t32 = encode_ternary(i_t, jnp.ones((4, 1, 1), jnp.float32))
    t16 = encode_ternary(i_t, jnp.ones((4, 1, 1), jnp.bfloat16))
    packed = int(t32.packed.size)
    assert t32.nbytes_wire() == packed + 4 * 4   # four fp32 scales
    assert t16.nbytes_wire() == packed + 4 * 2   # four bf16 scales
    scalar = encode_ternary(jnp.asarray([1, -1, 0], jnp.int8), jnp.float16(0.5))
    assert scalar.nbytes_wire() == int(scalar.packed.size) + 2


# --------------------------------------------------------------------------
# Per-direction split through the federated servers.
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fed_task():
    from repro.data import partition_iid, synthetic_classification
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 600, 10, 784, noise=3.0, n_test=100
    )
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        return float(jnp.mean(jnp.argmax(logits, -1) == yt_j)), 0.0

    return clients, params, mlp_mnist, eval_fn


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_asymmetric_direction_bytes(fed_task, mode):
    """fp16 residuals upstream only: upload shrinks, download unchanged —
    the Table-IV accounting reflects the direction split."""
    from repro.fed import FedConfig, run_federated
    from repro.optim import adam

    clients, params, apply_fn, eval_fn = fed_task
    base = dict(algorithm="tfedavg", mode=mode, participation=1.0,
                local_epochs=1, batch_size=32, rounds=2, seed=3)
    asym = CompressionSpec(
        upstream=CodecSpec(kind="ternary", residual="fp16"),
        downstream=CodecSpec(kind="ternary", residual="none"),
    )
    r_base = run_federated(apply_fn, params, clients, FedConfig(**base),
                           adam(1e-3), eval_fn, eval_every=2)
    r_asym = run_federated(apply_fn, params, clients,
                           FedConfig(**base, compression=asym),
                           adam(1e-3), eval_fn, eval_every=2)
    assert r_asym.upload_bytes < r_base.upload_bytes
    assert r_asym.download_bytes == r_base.download_bytes


def test_fedavg_with_downcast_both_ways(fed_task):
    """FedAvg over an fp16 wire: ~2× less traffic than raw fp32, learning
    still functional end to end (decode restores fp32)."""
    from repro.fed import FedConfig, run_federated
    from repro.optim import adam

    clients, params, apply_fn, eval_fn = fed_task
    base = dict(algorithm="fedavg", participation=1.0, local_epochs=1,
                batch_size=32, rounds=2, seed=4)
    half = CompressionSpec.symmetric(kind="fp16", residual="fp16")
    r32 = run_federated(apply_fn, params, clients, FedConfig(**base),
                        adam(1e-3), eval_fn, eval_every=2)
    r16 = run_federated(apply_fn, params, clients,
                        FedConfig(**base, compression=half),
                        adam(1e-3), eval_fn, eval_every=2)
    assert 1.8 < r32.upload_bytes / r16.upload_bytes < 2.2
    assert 1.8 < r32.download_bytes / r16.download_bytes < 2.2
