"""Channel model tests: the shared server-NIC bottleneck (max-min fair
share across concurrent transfers), its reduction to independent links
when the cap is infinite, and the lossy-link model (chunked Bernoulli
loss, retransmission accounting, async-upload contention)."""

import numpy as np
import pytest

from repro.comm import Channel, ChannelConfig


def _flat_cfg(**kw):
    base = dict(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.0,
                base_latency_s=1e-4, latency_jitter_s=0.0,
                compute_speed_sigma=0.0)
    base.update(kw)
    return ChannelConfig(**base)


def test_concurrent_transfers_contend_for_server_nic():
    """N simultaneous downloads through a saturated NIC take ~N× longer
    than a single one — the shared bottleneck a per-link model misses."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 8, seed=0)
    t_one = ch.transfer_concurrent([0], [1_000_000], "down")[0]
    t_four = ch.transfer_concurrent([0, 1, 2, 3], [1_000_000] * 4, "down")
    assert 0.99 < t_one < 1.01
    assert all(3.9 < t < 4.1 for t in t_four), t_four
    # and the log recorded one event per flow
    assert len(ch.log) == 5
    assert all(e.direction == "down" for e in ch.log)


def test_infinite_cap_reduces_to_independent_links():
    cfg = ChannelConfig(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.4,
                        latency_jitter_s=0.0)
    a, b = Channel(cfg, 6, seed=3), Channel(cfg, 6, seed=3)
    conc = a.transfer_concurrent(list(range(6)), [300_000] * 6, "down")
    solo = [b.transfer(k, 300_000, "down") for k in range(6)]
    np.testing.assert_allclose(conc, solo, atol=1e-9)


def test_zero_cap_means_uncapped_like_deadline_convention():
    """server_bandwidth_bytes_s=0 disables the bottleneck (0-or-inf, same
    convention as deadline_s) instead of hanging the fluid simulation."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=0.0)
    ch = Channel(cfg, 2, seed=0)
    times = ch.transfer_concurrent([0, 1], [1_000_000] * 2, "down")
    assert all(0.99 < t < 1.01 for t in times), times


def test_fair_share_respects_slow_client_links():
    """A client slower than its fair share only uses its own link rate; the
    leftover capacity goes to the fast clients (max-min)."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=2e6)
    ch = Channel(cfg, 4, seed=0)
    # hand-tune links: one 0.2 MB/s straggler, three 1 MB/s clients
    from repro.comm.channel import ClientLink
    ch.links[0] = ClientLink(0, 0.2e6, 1e-4, 1.0)
    times = ch.transfer_concurrent([0, 1, 2, 3], [600_000] * 4, "down")
    # straggler: 600k / 0.2 MB/s = 3 s regardless of the NIC
    assert 2.9 < times[0] < 3.1
    # fast three: share (2 MB/s − 0.2) / 3 = 0.6 → 1 s, then the finishers'
    # capacity redistributes; must be well under serialized 0.9 s each
    assert all(t < 1.2 for t in times[1:])


def test_sync_server_broadcast_contends(tmp_path):
    """End to end: capping the server NIC stretches the sync round's
    wall-clock while bytes stay identical."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 400, 10, 784, noise=3.0, n_test=80)
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))

    def eval_fn(p):
        logits = mlp_mnist(p, jnp.asarray(xt))
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yt))), 0.0

    def run(nic):
        chan = _flat_cfg(server_bandwidth_bytes_s=nic)
        cfg = FedConfig(algorithm="fedavg", participation=1.0, local_epochs=1,
                        batch_size=32, rounds=1, channel=chan, seed=0)
        return run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                             eval_fn, eval_every=1)

    wide = run(float("inf"))
    narrow = run(1e6)
    assert narrow.download_bytes == wide.download_bytes
    assert narrow.total_time_s > wide.total_time_s * 1.5


# ---------------------------------------------------------------------------
# Lossy links: Bernoulli chunk loss + retransmission (scenario layer).
# ---------------------------------------------------------------------------


def test_zero_loss_is_bytewise_and_streamwise_identical():
    """loss_rate=0 must not change ANYTHING — times, logged bytes, or the
    rng stream — vs a channel that never heard of the loss model."""
    a = Channel(_flat_cfg(latency_jitter_s=0.01), 8, seed=5)
    b = Channel(_flat_cfg(latency_jitter_s=0.01, loss_rate=0.0,
                          chunk_bytes=777, retransmit_timeout_s=9.9), 8, seed=5)
    for ch in (a, b):
        ch.transfer(0, 100_000, "down")
        ch.transfer_timed(1, 50_000, 3.0, "up")
        ch.transfer_concurrent([2, 3], [10_000, 20_000], "down")
    assert [(e.nbytes, e.seconds, e.retrans_bytes) for e in a.log] == \
           [(e.nbytes, e.seconds, e.retrans_bytes) for e in b.log]
    # and the rng streams stayed in lock-step
    assert a._rng.uniform() == b._rng.uniform()


def test_seeded_loss_is_deterministic():
    cfg = _flat_cfg(loss_rate=0.05, chunk_bytes=4096)
    logs = []
    for _ in range(2):
        ch = Channel(cfg, 4, seed=11)
        for k in range(4):
            ch.transfer(k, 500_000, "up")
        logs.append([(e.seconds, e.retrans_bytes, e.retries) for e in ch.log])
    assert logs[0] == logs[1]
    assert sum(r for _, r, _ in logs[0]) > 0  # 5% × ~122 chunks × 4: losses


def test_retransmission_accounting_sums_to_goodput_plus_overhead():
    """Wire time decomposes exactly: latency + (goodput+retrans)/bw +
    backoff timeouts; the summary ledger splits goodput from overhead."""
    cfg = _flat_cfg(loss_rate=0.1, chunk_bytes=8192,
                    retransmit_timeout_s=0.02, retransmit_backoff=2.0)
    ch = Channel(cfg, 2, seed=3)
    n = 400_000
    dt = ch.transfer(0, n, "up")
    e = ch.log[-1]
    assert e.nbytes == n and e.retrans_bytes > 0 and e.retries > 0
    # lower bound: timeouts are ≥ retries × base timeout (backoff ≥ 1)
    wire_t = (n + e.retrans_bytes) / 1e6
    assert dt >= 1e-4 + wire_t + e.retries * 0.02 - 1e-9
    # retransmitted bytes are whole chunks from this payload
    assert e.retrans_bytes % 8192 in (0, n % 8192)
    s = ch.summary()
    assert s["total_bytes"] == n                      # goodput ledger
    assert s["retrans_bytes"] == e.retrans_bytes      # overhead ledger
    assert 0 < s["goodput_fraction"] < 1
    assert s["goodput_fraction"] == n / (n + e.retrans_bytes)


def test_loss_rate_one_rejected():
    ch = Channel(_flat_cfg(loss_rate=1.0), 1, seed=0)
    with pytest.raises(ValueError, match="loss_rate"):
        ch.transfer(0, 1000, "up")


def test_concurrent_transfers_carry_loss_overhead():
    """Retransmitted chunks re-enter the shared pipe: lossy concurrent
    flows finish no earlier than lossless ones and log their overhead."""
    lossless = Channel(_flat_cfg(server_bandwidth_bytes_s=2e6), 4, seed=7)
    lossy = Channel(_flat_cfg(server_bandwidth_bytes_s=2e6, loss_rate=0.08,
                              chunk_bytes=16384), 4, seed=7)
    t0 = lossless.transfer_concurrent([0, 1, 2, 3], [400_000] * 4, "down")
    t1 = lossy.transfer_concurrent([0, 1, 2, 3], [400_000] * 4, "down")
    assert sum(e.retrans_bytes for e in lossy.log) > 0
    assert all(b >= a - 1e-12 for a, b in zip(t0, t1))
    assert sum(t1) > sum(t0)


def test_loss_stretches_sync_round_and_drops_stragglers():
    """Deadline interaction: the same fleet under loss pays retransmission
    time, so a deadline that everyone met now drops stragglers (bytes
    accounting unchanged — goodput is goodput)."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 400, 10, 784, noise=3.0, n_test=80)
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))

    def eval_fn(p):
        return 0.5, 0.0

    def run(loss):
        chan = _flat_cfg(mean_bandwidth_bytes_s=2e5, deadline_s=0.75,
                         loss_rate=loss, chunk_bytes=2048,
                         retransmit_timeout_s=0.1)
        cfg = FedConfig(algorithm="fedavg", participation=1.0, local_epochs=1,
                        batch_size=32, rounds=2, channel=chan, seed=0)
        return run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                             eval_fn, eval_every=2)

    clean = run(0.0)
    lossy = run(0.2)
    assert lossy.download_bytes == clean.download_bytes
    assert lossy.total_time_s > clean.total_time_s
    assert sum(lossy.dropped_per_round) >= sum(clean.dropped_per_round)
    assert lossy.telemetry["retrans_bytes"] > 0
    assert lossy.telemetry["goodput_fraction"] < 1.0


# ---------------------------------------------------------------------------
# Async-upload NIC contention (transfer_timed).
# ---------------------------------------------------------------------------


def test_timed_uncapped_matches_plain_transfer():
    a = Channel(_flat_cfg(latency_jitter_s=0.02), 3, seed=9)
    b = Channel(_flat_cfg(latency_jitter_s=0.02), 3, seed=9)
    for k in range(3):
        ta = a.transfer(k, 123_456, "up")
        tb = b.transfer_timed(k, 123_456, float(k), "up")
        assert ta == tb  # bit-identical, not just close


def test_timed_overlapping_uploads_contend():
    """Bursty async arrivals share the server NIC: four overlapping uploads
    each take ~4× the solo time; spread-out uploads do not."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 8, seed=0)
    solo = ch.transfer_timed(0, 1_000_000, 0.0, "up", now_s=0.0)
    assert 0.99 < solo < 1.01
    burst = Channel(cfg, 8, seed=0)
    times = [burst.transfer_timed(k, 1_000_000, 100.0, "up", now_s=100.0)
             for k in range(4)]
    assert times[0] < times[-1]          # later joiners see more contention
    assert times[-1] > 2.0               # far from the uncontended 1 s
    spread = Channel(cfg, 8, seed=0)
    apart = [spread.transfer_timed(k, 1_000_000, k * 50.0, "up",
                                   now_s=k * 50.0) for k in range(4)]
    assert all(0.99 < t < 1.01 for t in apart)


def test_timed_contention_isolated_per_direction():
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 4, seed=0)
    ch.transfer_timed(0, 1_000_000, 0.0, "up", now_s=0.0)
    down = ch.transfer_timed(1, 1_000_000, 0.0, "down", now_s=0.0)
    assert 0.99 < down < 1.01  # the up flow does not slow the down flow


# ---------------------------------------------------------------------------
# Gilbert–Elliott bursty loss.
# ---------------------------------------------------------------------------


def _ge_cfg(**kw):
    # π_bad = 0.01/(0.01+0.08) = 1/9, so the expected retransmissions per
    # chunk are π_bad·p_b/(1−p_b) = 1/9 — the same as iid loss_rate=0.1
    # (p/(1−p) = 1/9): matched mean loss budget, bursty delivery.
    base = dict(loss_model="gilbert_elliott", chunk_bytes=2048,
                ge_p_good_bad=0.01, ge_p_bad_good=0.08,
                ge_loss_good=0.0, ge_loss_bad=0.5)
    base.update(kw)
    return _flat_cfg(**base)


def test_ge_lossless_is_rng_stream_untouched():
    """Both state loss rates 0 ⇒ the GE channel is bit-identical to a
    channel that never heard of any loss model — times, logged bytes, and
    the rng stream."""
    a = Channel(_flat_cfg(latency_jitter_s=0.01), 8, seed=5)
    b = Channel(_ge_cfg(latency_jitter_s=0.01, ge_loss_good=0.0,
                        ge_loss_bad=0.0, retransmit_timeout_s=9.9), 8, seed=5)
    for ch in (a, b):
        ch.transfer(0, 100_000, "down")
        ch.transfer_timed(1, 50_000, 3.0, "up")
        ch.transfer_concurrent([2, 3], [10_000, 20_000], "down")
        ch.transfer_batch(np.arange(4), np.full(4, 30_000), "up")
    assert [(e.nbytes, e.seconds, e.retrans_bytes) for e in a.log] == \
           [(e.nbytes, e.seconds, e.retrans_bytes) for e in b.log]
    assert a._rng.uniform() == b._rng.uniform()


def test_ge_burstier_than_iid_at_matched_marginal_rate():
    """Same mean retransmission budget per chunk as iid loss_rate=0.1 (see
    _ge_cfg), but GE concentrates it in runs: the per-transfer retry counts
    have visibly heavier spread (and more zero-loss transfers) than iid."""
    n, nbytes = 300, 100 * 2048          # 100 chunks per transfer
    ge = Channel(_ge_cfg(), 1, seed=42)
    iid = Channel(_flat_cfg(loss_rate=0.1, chunk_bytes=2048), 1, seed=42)
    for ch in (ge, iid):
        for _ in range(n):
            ch.transfer(0, nbytes, "up")
    r_ge = np.array([e.retries for e in ge.log], dtype=float)
    r_iid = np.array([e.retries for e in iid.log], dtype=float)
    # matched marginal: mean retries per transfer within 25% of each other
    assert abs(r_ge.mean() - r_iid.mean()) < 0.25 * r_iid.mean()
    # burstiness: variance well above iid at the same marginal rate
    assert r_ge.var() > 3.0 * r_iid.var(), (r_ge.var(), r_iid.var())
    # ... and runs of good chunks mean more completely clean transfers
    assert (r_ge == 0).sum() > (r_iid == 0).sum()


def test_ge_seeded_runs_are_deterministic():
    logs = []
    for _ in range(2):
        ch = Channel(_ge_cfg(ge_p_good_bad=0.2, ge_p_bad_good=0.2), 2, seed=11)
        for k in range(2):
            ch.transfer(k, 300_000, "up")
        logs.append([(e.seconds, e.retrans_bytes, e.retries) for e in ch.log])
    assert logs[0] == logs[1]
    assert sum(r for _, r, _ in logs[0]) > 0


def test_ge_batch_equals_scalar_penalties_laid_end_to_end():
    """Each transfer's chain is independent, so the batched penalty path is
    exactly the scalar penalties in sequence (no iid-style draw fold)."""
    a = Channel(_ge_cfg(), 4, seed=3)
    b = Channel(_ge_cfg(), 4, seed=3)
    nb = np.array([150_000, 0, 80_000, 300_000])
    retrans, delay, retries = a._loss_penalty_batch(nb)
    pens = [b._ge_loss_penalty(int(n)) for n in nb]
    assert list(retrans) == [p[0] for p in pens]
    np.testing.assert_allclose(delay, [p[1] for p in pens], atol=1e-12)
    assert list(retries) == [p[2] for p in pens]
    assert retrans.sum() > 0 and retrans[1] == 0    # 0-byte transfer clean
    # ... and transfer_batch(compat=True) IS the scalar call order
    c = Channel(_ge_cfg(), 4, seed=3)
    d = Channel(_ge_cfg(), 4, seed=3)
    sc = c.transfer_batch(np.arange(4), nb, "up", compat=True)
    sd = [d.transfer(k, int(n), "up") for k, n in enumerate(nb)]
    np.testing.assert_allclose(sc, sd, atol=1e-12)


def test_ge_retrans_accounting_feeds_summary_ledger():
    ch = Channel(_ge_cfg(ge_p_good_bad=0.2, ge_p_bad_good=0.2), 1, seed=1)
    n = 400_000
    ch.transfer(0, n, "up")
    e = ch.log[-1]
    assert e.retrans_bytes > 0 and e.retries > 0
    s = ch.summary()
    assert s["total_bytes"] == n
    assert s["goodput_fraction"] == n / (n + e.retrans_bytes)


def test_ge_and_model_validation():
    with pytest.raises(ValueError, match="ge_loss_bad"):
        Channel(_ge_cfg(ge_loss_bad=1.0), 1, seed=0).transfer(0, 1000, "up")
    with pytest.raises(ValueError, match="ge_p_bad_good"):
        Channel(_ge_cfg(ge_p_bad_good=1.5), 1, seed=0).transfer(0, 1000, "up")
    with pytest.raises(ValueError, match="loss_model"):
        Channel(_flat_cfg(loss_model="bursty?"), 1, seed=0).transfer(
            0, 1000, "up")
    with pytest.raises(ValueError, match="loss_model"):
        Channel(_flat_cfg(loss_model="bursty?"), 1, seed=0).transfer_batch(
            np.array([0]), np.array([1000]), "up")
