"""Channel model tests: the shared server-NIC bottleneck (max-min fair
share across concurrent transfers), its reduction to independent links
when the cap is infinite, and the lossy-link model (chunked Bernoulli
loss, retransmission accounting, async-upload contention)."""

import numpy as np
import pytest

from repro.comm import Channel, ChannelConfig


def _flat_cfg(**kw):
    base = dict(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.0,
                base_latency_s=1e-4, latency_jitter_s=0.0,
                compute_speed_sigma=0.0)
    base.update(kw)
    return ChannelConfig(**base)


def test_concurrent_transfers_contend_for_server_nic():
    """N simultaneous downloads through a saturated NIC take ~N× longer
    than a single one — the shared bottleneck a per-link model misses."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 8, seed=0)
    t_one = ch.transfer_concurrent([0], [1_000_000], "down")[0]
    t_four = ch.transfer_concurrent([0, 1, 2, 3], [1_000_000] * 4, "down")
    assert 0.99 < t_one < 1.01
    assert all(3.9 < t < 4.1 for t in t_four), t_four
    # and the log recorded one event per flow
    assert len(ch.log) == 5
    assert all(e.direction == "down" for e in ch.log)


def test_infinite_cap_reduces_to_independent_links():
    cfg = ChannelConfig(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.4,
                        latency_jitter_s=0.0)
    a, b = Channel(cfg, 6, seed=3), Channel(cfg, 6, seed=3)
    conc = a.transfer_concurrent(list(range(6)), [300_000] * 6, "down")
    solo = [b.transfer(k, 300_000, "down") for k in range(6)]
    np.testing.assert_allclose(conc, solo, atol=1e-9)


def test_zero_cap_means_uncapped_like_deadline_convention():
    """server_bandwidth_bytes_s=0 disables the bottleneck (0-or-inf, same
    convention as deadline_s) instead of hanging the fluid simulation."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=0.0)
    ch = Channel(cfg, 2, seed=0)
    times = ch.transfer_concurrent([0, 1], [1_000_000] * 2, "down")
    assert all(0.99 < t < 1.01 for t in times), times


def test_fair_share_respects_slow_client_links():
    """A client slower than its fair share only uses its own link rate; the
    leftover capacity goes to the fast clients (max-min)."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=2e6)
    ch = Channel(cfg, 4, seed=0)
    # hand-tune links: one 0.2 MB/s straggler, three 1 MB/s clients
    from repro.comm.channel import ClientLink
    ch.links[0] = ClientLink(0, 0.2e6, 1e-4, 1.0)
    times = ch.transfer_concurrent([0, 1, 2, 3], [600_000] * 4, "down")
    # straggler: 600k / 0.2 MB/s = 3 s regardless of the NIC
    assert 2.9 < times[0] < 3.1
    # fast three: share (2 MB/s − 0.2) / 3 = 0.6 → 1 s, then the finishers'
    # capacity redistributes; must be well under serialized 0.9 s each
    assert all(t < 1.2 for t in times[1:])


def test_sync_server_broadcast_contends(tmp_path):
    """End to end: capping the server NIC stretches the sync round's
    wall-clock while bytes stay identical."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 400, 10, 784, noise=3.0, n_test=80)
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))

    def eval_fn(p):
        logits = mlp_mnist(p, jnp.asarray(xt))
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yt))), 0.0

    def run(nic):
        chan = _flat_cfg(server_bandwidth_bytes_s=nic)
        cfg = FedConfig(algorithm="fedavg", participation=1.0, local_epochs=1,
                        batch_size=32, rounds=1, channel=chan, seed=0)
        return run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                             eval_fn, eval_every=1)

    wide = run(float("inf"))
    narrow = run(1e6)
    assert narrow.download_bytes == wide.download_bytes
    assert narrow.total_time_s > wide.total_time_s * 1.5


# ---------------------------------------------------------------------------
# Lossy links: Bernoulli chunk loss + retransmission (scenario layer).
# ---------------------------------------------------------------------------


def test_zero_loss_is_bytewise_and_streamwise_identical():
    """loss_rate=0 must not change ANYTHING — times, logged bytes, or the
    rng stream — vs a channel that never heard of the loss model."""
    a = Channel(_flat_cfg(latency_jitter_s=0.01), 8, seed=5)
    b = Channel(_flat_cfg(latency_jitter_s=0.01, loss_rate=0.0,
                          chunk_bytes=777, retransmit_timeout_s=9.9), 8, seed=5)
    for ch in (a, b):
        ch.transfer(0, 100_000, "down")
        ch.transfer_timed(1, 50_000, 3.0, "up")
        ch.transfer_concurrent([2, 3], [10_000, 20_000], "down")
    assert [(e.nbytes, e.seconds, e.retrans_bytes) for e in a.log] == \
           [(e.nbytes, e.seconds, e.retrans_bytes) for e in b.log]
    # and the rng streams stayed in lock-step
    assert a._rng.uniform() == b._rng.uniform()


def test_seeded_loss_is_deterministic():
    cfg = _flat_cfg(loss_rate=0.05, chunk_bytes=4096)
    logs = []
    for _ in range(2):
        ch = Channel(cfg, 4, seed=11)
        for k in range(4):
            ch.transfer(k, 500_000, "up")
        logs.append([(e.seconds, e.retrans_bytes, e.retries) for e in ch.log])
    assert logs[0] == logs[1]
    assert sum(r for _, r, _ in logs[0]) > 0  # 5% × ~122 chunks × 4: losses


def test_retransmission_accounting_sums_to_goodput_plus_overhead():
    """Wire time decomposes exactly: latency + (goodput+retrans)/bw +
    backoff timeouts; the summary ledger splits goodput from overhead."""
    cfg = _flat_cfg(loss_rate=0.1, chunk_bytes=8192,
                    retransmit_timeout_s=0.02, retransmit_backoff=2.0)
    ch = Channel(cfg, 2, seed=3)
    n = 400_000
    dt = ch.transfer(0, n, "up")
    e = ch.log[-1]
    assert e.nbytes == n and e.retrans_bytes > 0 and e.retries > 0
    # lower bound: timeouts are ≥ retries × base timeout (backoff ≥ 1)
    wire_t = (n + e.retrans_bytes) / 1e6
    assert dt >= 1e-4 + wire_t + e.retries * 0.02 - 1e-9
    # retransmitted bytes are whole chunks from this payload
    assert e.retrans_bytes % 8192 in (0, n % 8192)
    s = ch.summary()
    assert s["total_bytes"] == n                      # goodput ledger
    assert s["retrans_bytes"] == e.retrans_bytes      # overhead ledger
    assert 0 < s["goodput_fraction"] < 1
    assert s["goodput_fraction"] == n / (n + e.retrans_bytes)


def test_loss_rate_one_rejected():
    ch = Channel(_flat_cfg(loss_rate=1.0), 1, seed=0)
    with pytest.raises(ValueError, match="loss_rate"):
        ch.transfer(0, 1000, "up")


def test_concurrent_transfers_carry_loss_overhead():
    """Retransmitted chunks re-enter the shared pipe: lossy concurrent
    flows finish no earlier than lossless ones and log their overhead."""
    lossless = Channel(_flat_cfg(server_bandwidth_bytes_s=2e6), 4, seed=7)
    lossy = Channel(_flat_cfg(server_bandwidth_bytes_s=2e6, loss_rate=0.08,
                              chunk_bytes=16384), 4, seed=7)
    t0 = lossless.transfer_concurrent([0, 1, 2, 3], [400_000] * 4, "down")
    t1 = lossy.transfer_concurrent([0, 1, 2, 3], [400_000] * 4, "down")
    assert sum(e.retrans_bytes for e in lossy.log) > 0
    assert all(b >= a - 1e-12 for a, b in zip(t0, t1))
    assert sum(t1) > sum(t0)


def test_loss_stretches_sync_round_and_drops_stragglers():
    """Deadline interaction: the same fleet under loss pays retransmission
    time, so a deadline that everyone met now drops stragglers (bytes
    accounting unchanged — goodput is goodput)."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 400, 10, 784, noise=3.0, n_test=80)
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))

    def eval_fn(p):
        return 0.5, 0.0

    def run(loss):
        chan = _flat_cfg(mean_bandwidth_bytes_s=2e5, deadline_s=0.75,
                         loss_rate=loss, chunk_bytes=2048,
                         retransmit_timeout_s=0.1)
        cfg = FedConfig(algorithm="fedavg", participation=1.0, local_epochs=1,
                        batch_size=32, rounds=2, channel=chan, seed=0)
        return run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                             eval_fn, eval_every=2)

    clean = run(0.0)
    lossy = run(0.2)
    assert lossy.download_bytes == clean.download_bytes
    assert lossy.total_time_s > clean.total_time_s
    assert sum(lossy.dropped_per_round) >= sum(clean.dropped_per_round)
    assert lossy.telemetry["retrans_bytes"] > 0
    assert lossy.telemetry["goodput_fraction"] < 1.0


# ---------------------------------------------------------------------------
# Async-upload NIC contention (transfer_timed).
# ---------------------------------------------------------------------------


def test_timed_uncapped_matches_plain_transfer():
    a = Channel(_flat_cfg(latency_jitter_s=0.02), 3, seed=9)
    b = Channel(_flat_cfg(latency_jitter_s=0.02), 3, seed=9)
    for k in range(3):
        ta = a.transfer(k, 123_456, "up")
        tb = b.transfer_timed(k, 123_456, float(k), "up")
        assert ta == tb  # bit-identical, not just close


def test_timed_overlapping_uploads_contend():
    """Bursty async arrivals share the server NIC: four overlapping uploads
    each take ~4× the solo time; spread-out uploads do not."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 8, seed=0)
    solo = ch.transfer_timed(0, 1_000_000, 0.0, "up", now_s=0.0)
    assert 0.99 < solo < 1.01
    burst = Channel(cfg, 8, seed=0)
    times = [burst.transfer_timed(k, 1_000_000, 100.0, "up", now_s=100.0)
             for k in range(4)]
    assert times[0] < times[-1]          # later joiners see more contention
    assert times[-1] > 2.0               # far from the uncontended 1 s
    spread = Channel(cfg, 8, seed=0)
    apart = [spread.transfer_timed(k, 1_000_000, k * 50.0, "up",
                                   now_s=k * 50.0) for k in range(4)]
    assert all(0.99 < t < 1.01 for t in apart)


def test_timed_contention_isolated_per_direction():
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 4, seed=0)
    ch.transfer_timed(0, 1_000_000, 0.0, "up", now_s=0.0)
    down = ch.transfer_timed(1, 1_000_000, 0.0, "down", now_s=0.0)
    assert 0.99 < down < 1.01  # the up flow does not slow the down flow
