"""Channel model tests: the shared server-NIC bottleneck (max-min fair
share across concurrent transfers) and its reduction to independent links
when the cap is infinite."""

import numpy as np

from repro.comm import Channel, ChannelConfig


def _flat_cfg(**kw):
    base = dict(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.0,
                base_latency_s=1e-4, latency_jitter_s=0.0,
                compute_speed_sigma=0.0)
    base.update(kw)
    return ChannelConfig(**base)


def test_concurrent_transfers_contend_for_server_nic():
    """N simultaneous downloads through a saturated NIC take ~N× longer
    than a single one — the shared bottleneck a per-link model misses."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=1e6)
    ch = Channel(cfg, 8, seed=0)
    t_one = ch.transfer_concurrent([0], [1_000_000], "down")[0]
    t_four = ch.transfer_concurrent([0, 1, 2, 3], [1_000_000] * 4, "down")
    assert 0.99 < t_one < 1.01
    assert all(3.9 < t < 4.1 for t in t_four), t_four
    # and the log recorded one event per flow
    assert len(ch.log) == 5
    assert all(e.direction == "down" for e in ch.log)


def test_infinite_cap_reduces_to_independent_links():
    cfg = ChannelConfig(mean_bandwidth_bytes_s=1e6, bandwidth_sigma=0.4,
                        latency_jitter_s=0.0)
    a, b = Channel(cfg, 6, seed=3), Channel(cfg, 6, seed=3)
    conc = a.transfer_concurrent(list(range(6)), [300_000] * 6, "down")
    solo = [b.transfer(k, 300_000, "down") for k in range(6)]
    np.testing.assert_allclose(conc, solo, atol=1e-9)


def test_zero_cap_means_uncapped_like_deadline_convention():
    """server_bandwidth_bytes_s=0 disables the bottleneck (0-or-inf, same
    convention as deadline_s) instead of hanging the fluid simulation."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=0.0)
    ch = Channel(cfg, 2, seed=0)
    times = ch.transfer_concurrent([0, 1], [1_000_000] * 2, "down")
    assert all(0.99 < t < 1.01 for t in times), times


def test_fair_share_respects_slow_client_links():
    """A client slower than its fair share only uses its own link rate; the
    leftover capacity goes to the fast clients (max-min)."""
    cfg = _flat_cfg(server_bandwidth_bytes_s=2e6)
    ch = Channel(cfg, 4, seed=0)
    # hand-tune links: one 0.2 MB/s straggler, three 1 MB/s clients
    from repro.comm.channel import ClientLink
    ch.links[0] = ClientLink(0, 0.2e6, 1e-4, 1.0)
    times = ch.transfer_concurrent([0, 1, 2, 3], [600_000] * 4, "down")
    # straggler: 600k / 0.2 MB/s = 3 s regardless of the NIC
    assert 2.9 < times[0] < 3.1
    # fast three: share (2 MB/s − 0.2) / 3 = 0.6 → 1 s, then the finishers'
    # capacity redistributes; must be well under serialized 0.9 s each
    assert all(t < 1.2 for t in times[1:])


def test_sync_server_broadcast_contends(tmp_path):
    """End to end: capping the server NIC stretches the sync round's
    wall-clock while bytes stay identical."""
    import jax
    import jax.numpy as jnp

    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 400, 10, 784, noise=3.0, n_test=80)
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))

    def eval_fn(p):
        logits = mlp_mnist(p, jnp.asarray(xt))
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yt))), 0.0

    def run(nic):
        chan = _flat_cfg(server_bandwidth_bytes_s=nic)
        cfg = FedConfig(algorithm="fedavg", participation=1.0, local_epochs=1,
                        batch_size=32, rounds=1, channel=chan, seed=0)
        return run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                             eval_fn, eval_every=1)

    wide = run(float("inf"))
    narrow = run(1e6)
    assert narrow.download_bytes == wide.download_bytes
    assert narrow.total_time_s > wide.total_time_s * 1.5
