"""Cross-process federation: N real client OS processes streaming fused
ternary updates over loopback TCP must produce a root aggregate
byte-identical to the in-process reference for the same seeds, with the
byte ledger metered from actual socket traffic.

The socket rounds have their own hard timeouts (accept/recv), so a hung
child fails the test instead of hanging the suite."""

import multiprocessing as mp

import numpy as np
import pytest

from repro.fed.mp_server import (
    client_update_blob,
    client_weight,
    demo_params,
    params_hash,
    run_inprocess_reference,
    run_socket_round,
)

pytestmark = pytest.mark.skipif(
    "spawn" not in mp.get_all_start_methods(),
    reason="platform lacks multiprocessing spawn start method",
)

# single-core CI: N child interpreters serialize their JAX imports, so the
# budget is generous — but finite, a hung accept loop must fail, not hang.
TIMEOUT_S = 300.0
N_CLIENTS = 8   # the acceptance floor: ≥ 8 real client processes
SEED = 7


@pytest.fixture(scope="module")
def sync_round():
    params = demo_params(seed=SEED)
    res = run_socket_round(params, N_CLIENTS, seed=SEED, mode="sync",
                           timeout_s=TIMEOUT_S)
    return params, res


def test_sync_aggregate_byte_identical_to_inprocess(sync_round):
    """Same seeds, clients as real OS processes vs in-process calls: the
    final weight hash must match exactly (fused encode is deterministic
    across process boundaries; the sync barrier replays client_id order)."""
    params, res = sync_round
    ref = run_inprocess_reference(params, N_CLIENTS, seed=SEED, mode="sync")
    assert params_hash(res.params) == params_hash(ref)


def test_sync_round_served_all_clients(sync_round):
    _params, res = sync_round
    assert res.n_clients == N_CLIENTS
    assert sorted(res.arrivals) == list(range(N_CLIENTS))


def test_ledger_metered_from_socket_traffic(sync_round):
    """Upload bytes come from FrameDecoder.bytes_in (real reads), so they
    must exceed the summed wire payloads by exactly the framing overhead:
    per client one HELLO frame + one UPDATE header/meta."""
    params, res = sync_round
    assert res.payload_bytes > 0
    assert res.upload_bytes > res.payload_bytes
    overhead = res.framing_overhead_bytes
    # HELLO (~16 B header + v2 meta: client_id/proto/nonce/attempt) plus the
    # UPDATE header/meta per client: tight sane bounds
    assert N_CLIENTS * 30 <= overhead <= N_CLIENTS * 200
    # the broadcast went down once per client inside a BCAST frame + DONE
    from repro.comm.wire import encode_update

    bcast = len(encode_update(params))
    assert res.download_bytes >= N_CLIENTS * bcast


def test_update_blob_is_pure_function_of_inputs():
    """The client program is deterministic: same (params, id, seed) → same
    bytes; different id or seed → different bytes."""
    params = demo_params(seed=SEED)
    a = client_update_blob(params, 3, SEED)
    b = client_update_blob(params, 3, SEED)
    c = client_update_blob(params, 4, SEED)
    d = client_update_blob(params, 3, SEED + 1)
    assert a == b and a != c and a != d
    assert client_weight(3) == client_weight(3) > 0


def test_buffered_mode_matches_reference_in_arrival_order():
    """Buffered (FedBuf-style η-mix every K arrivals) folds in true socket
    arrival order; the reference replaying that recorded order must match
    byte-for-byte."""
    params = demo_params(seed=SEED + 1)
    res = run_socket_round(params, 4, seed=SEED + 1, mode="buffered",
                           buffer_k=3, eta=0.5, timeout_s=TIMEOUT_S)
    ref = run_inprocess_reference(params, 4, seed=SEED + 1, mode="buffered",
                                  buffer_k=3, eta=0.5, order=res.arrivals)
    assert params_hash(res.params) == params_hash(ref)
    # and the mixed model is not the untouched global
    assert params_hash(res.params) != params_hash(params)


def test_inprocess_reference_order_sensitivity():
    """Buffered mixing IS order-sensitive (that is why the reference takes
    the recorded arrival order) while sync is order-insensitive by
    construction (the barrier sorts)."""
    params = demo_params(seed=SEED)
    fwd = run_inprocess_reference(params, 5, seed=SEED, mode="buffered",
                                  buffer_k=2, order=[0, 1, 2, 3, 4])
    rev = run_inprocess_reference(params, 5, seed=SEED, mode="buffered",
                                  buffer_k=2, order=[4, 3, 2, 1, 0])
    assert params_hash(fwd) != params_hash(rev)


def test_nofault_round_has_clean_fault_surface(sync_round):
    """Without faults the new fault-tolerance surface must be inert: every
    outcome ok, a FULL commit, zero drops/retries/resumes/escalations, and
    a balanced ledger — the PR-7 byte-identity contract rides on this."""
    _params, res = sync_round
    assert res.committed == "full"
    assert all(v == "ok" for v in res.outcomes.values())
    assert len(res.outcomes) == N_CLIENTS
    assert res.dropped_update_bytes == 0
    assert res.retries == 0 and res.resumed_bytes == 0
    assert res.escalations == {"terminated": 0, "killed": 0}
    assert res.chaos is None
    led = res.ledger()
    assert led["balance_ok"]
    assert res.shipped_update_bytes == res.ingested_update_bytes > 0


def test_bad_args_rejected():
    params = demo_params()
    with pytest.raises(ValueError, match="n_clients"):
        run_socket_round(params, 0)
    with pytest.raises(ValueError, match="mode"):
        run_socket_round(params, 1, mode="nope")
    with pytest.raises(ValueError, match="quorum_frac"):
        run_socket_round(params, 1, quorum_frac=0.0)
    with pytest.raises(ValueError, match="quorum_frac"):
        run_socket_round(params, 1, quorum_frac=1.5)


def test_validate_update_weight_meta_rejected():
    """A missing / non-numeric / non-finite / negative weight meta is a
    malformed frame: FrameError, which the handler maps onto the
    "rejected" outcome — never a KeyError crash, never a poisoned
    denominator."""
    from repro.comm.transport import FT_UPDATE, Frame, FrameError
    from repro.fed.mp_server import _validate_update

    def update(meta):
        meta = {"client_id": 3, **meta}
        return Frame(ftype=FT_UPDATE, meta=meta, payload=b"x")

    assert _validate_update(update({"weight": 12.5}), 3) == 12.5
    assert _validate_update(update({"weight": 0}), 3) == 0.0  # empty shard ok
    for bad in ({}, {"weight": None}, {"weight": "forty"},
                {"weight": float("nan")}, {"weight": float("inf")},
                {"weight": -1.0}):
        with pytest.raises(FrameError, match="weight"):
            _validate_update(update(bad), 3)


def test_defended_round_quarantines_attackers_and_matches_honest_ref():
    """The poison-smoke contract over real sockets: seeded nan_poison
    attackers land, get outcome "quarantined", the extended ledger
    balances, and the committed root aggregate is byte-identical to the
    in-process reference over the HONEST survivors only."""
    from repro.fed.attackers import AttackConfig, attacker_ids
    from repro.fed.defense import DefenseConfig

    n, n_atk = 5, 2
    params = demo_params(seed=SEED + 2)
    attack = AttackConfig(kind="nan_poison", n_attackers=n_atk, seed=SEED)
    attackers = attacker_ids(attack, n)
    res = run_socket_round(
        params, n, seed=SEED + 2, mode="sync", timeout_s=TIMEOUT_S,
        defense=DefenseConfig(enabled=True), attack=attack,
        quorum_frac=(n - n_atk) / n,      # quarantined never count as landed
    )
    assert res.committed in ("full", "quorum")
    assert {cid for cid, v in res.outcomes.items()
            if v == "quarantined"} == set(attackers)
    assert res.defense["quarantined_updates"] == n_atk
    assert res.quarantined_update_bytes > 0
    led = res.ledger()
    assert led["balance_ok"]
    assert (res.shipped_update_bytes
            == res.ingested_update_bytes + res.dropped_update_bytes
            + res.quarantined_update_bytes)
    honest = sorted(set(range(n)) - set(attackers))
    ref = run_inprocess_reference(params, n, seed=SEED + 2, mode="sync",
                                  order=honest)
    assert params_hash(res.params) == params_hash(ref)


def test_defense_on_honest_socket_round_is_byte_identical():
    """Defense on, no attackers: same root hash as the undefended round —
    the gate inspects but never mutates."""
    from repro.fed.defense import DefenseConfig

    params = demo_params(seed=SEED + 3)
    res = run_socket_round(params, 4, seed=SEED + 3, mode="sync",
                           timeout_s=TIMEOUT_S,
                           defense=DefenseConfig(enabled=True))
    assert all(v == "ok" for v in res.outcomes.values())
    assert res.defense["quarantined_updates"] == 0
    assert res.ledger()["balance_ok"]
    ref = run_inprocess_reference(params, 4, seed=SEED + 3, mode="sync")
    assert params_hash(res.params) == params_hash(ref)


def test_aggregate_value_is_weighted_mean():
    """Cross-check the in-process reference against a dense numpy weighted
    mean of the decoded client updates (loose tolerance: fused kernel sums
    in a different float order)."""
    import jax

    from repro.comm.wire import decode_update, encode_update
    from repro.fed.simulation import dequantize_tree

    params = demo_params(seed=3, d=16, depth=1)
    n = 3
    start = decode_update(encode_update(params))
    blobs = [client_update_blob(start, cid, 3) for cid in range(n)]
    w = np.array([client_weight(cid) for cid in range(n)])
    dense = [dequantize_tree(decode_update(b)) for b in blobs]
    ref = run_inprocess_reference(params, n, seed=3, mode="sync")
    leaves_ref = jax.tree_util.tree_leaves(ref)
    stacked = [jax.tree_util.tree_leaves(d) for d in dense]
    for i, leaf in enumerate(leaves_ref):
        manual = sum(w[k] * np.asarray(stacked[k][i], np.float64)
                     for k in range(n)) / w.sum()
        np.testing.assert_allclose(np.asarray(leaf, np.float64), manual,
                                   rtol=2e-5, atol=2e-5)
