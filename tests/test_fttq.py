"""Unit tests for the FTTQ quantizer (paper §III.A, Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fttq as F

CFG = F.FTTQConfig()


def test_scale_layer_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 7.3
    s = F.scale_layer(x)
    assert float(jnp.max(jnp.abs(s))) <= 1.0 + 1e-6


def test_threshold_rules():
    x = jnp.array([[0.1, -0.5, 0.9, -0.2]])
    assert float(F.fttq_threshold(x, 0.7, "mean")) == pytest.approx(
        0.7 * 0.425, rel=1e-5
    )
    assert float(F.fttq_threshold(x, 0.05, "max")) == pytest.approx(
        0.05 * 0.9, rel=1e-5
    )
    with pytest.raises(ValueError):
        F.fttq_threshold(x, 0.7, "nope")


def test_threshold_bound_eq9():
    """Paper eq. (9): the mean-rule Δ is bounded by T_k (on scaled weights)."""
    for seed in range(5):
        x = F.scale_layer(jax.random.normal(jax.random.PRNGKey(seed), (128, 64)))
        d = F.fttq_threshold(x, 0.7, "mean")
        assert float(d) <= 0.7 + 1e-6


def test_ternarize_values():
    x = jnp.array([0.9, -0.9, 0.01, -0.01, 0.0])
    t = F.ternarize(x, jnp.asarray(0.5))
    np.testing.assert_array_equal(np.asarray(t), [1, -1, 0, 0, 0])


def test_quantize_output_is_ternary_times_scale():
    theta = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    wq = F.init_wq(theta, CFG)
    out = F.fttq_quantize(theta, wq, CFG.t_k)
    vals = np.unique(np.round(np.abs(np.asarray(out)), 6))
    assert len(vals) <= 2  # {0, w_q}
    assert float(wq) > 0


def test_wq_init_is_l2_optimum():
    """Prop 4.1 / eq. 20: w* = mean(|θ_i| over quantized positions) minimizes
    ||θ − w·I_t||² for fixed I_t — check against brute-force line search."""
    theta = jax.random.normal(jax.random.PRNGKey(2), (256, 128))
    wq = float(F.init_wq(theta, CFG))
    ts = F.scale_layer(theta)
    d = F.fttq_threshold(ts, CFG.t_k)
    it = np.asarray(F.ternarize(ts, d))
    th = np.asarray(theta)

    def err(w):
        return np.sum((th - w * it) ** 2)

    ws = np.linspace(wq * 0.5, wq * 1.5, 201)
    errs = [err(w) for w in ws]
    assert err(wq) <= min(errs) + 1e-3


def test_ste_gradients():
    theta = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    wq = F.init_wq(theta, CFG)

    def loss(t, w):
        return jnp.sum(F.fttq_quantize(t, w, CFG.t_k) ** 2)

    g_t, g_w = jax.grad(loss, argnums=(0, 1))(theta, wq)
    assert g_t.shape == theta.shape
    assert g_w.shape == ()
    # ∂J/∂w_q = Σ g·I_t (Alg. 1)
    ts = F.scale_layer(theta)
    it = F.ternarize(ts, F.fttq_threshold(ts, CFG.t_k))
    expected_gw = jnp.sum(2 * F.fttq_quantize(theta, wq, CFG.t_k) * it)
    assert float(g_w) == pytest.approx(float(expected_gw), rel=1e-4)
    # latent grads scaled by w_q on quantized positions, 1 elsewhere
    g_out = 2 * F.fttq_quantize(theta, wq, CFG.t_k)
    expected_gt = np.where(np.asarray(it) != 0, np.asarray(g_out) * float(wq),
                           np.asarray(g_out))
    np.testing.assert_allclose(np.asarray(g_t), expected_gt, rtol=1e-5)


def test_quantize_tree_policy():
    params = {
        "layer": {"w": jnp.ones((8, 4)), "bias": jnp.ones((4,))},
        "attn_norm": jnp.ones((8, 8)),       # excluded by name
        "embed": {"table": jnp.ones((16, 8))},  # excluded by default
        "stack": {"w_in": jnp.ones((3, 8, 4))},  # per-layer factors
    }
    wq = F.init_wq_tree(params, CFG)
    assert wq["layer"]["bias"] is None
    assert wq["attn_norm"] is None
    assert wq["embed"]["table"] is None
    assert wq["stack"]["w_in"].shape == (3, 1, 1)
    q = F.quantize_tree(params, wq, CFG)
    assert q["layer"]["bias"].shape == (4,)
    np.testing.assert_array_equal(np.asarray(q["embed"]["table"]),
                                  np.asarray(params["embed"]["table"]))


def test_quantize_embed_flag():
    cfg = F.FTTQConfig(quantize_embed=True)
    params = {"embed": {"table": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}}
    wq = F.init_wq_tree(params, cfg)
    assert wq["embed"]["table"] is not None


def test_ternary_stats():
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (128, 64))}
    stats = F.ternary_stats(params, CFG)
    assert stats["quantized_params"] == 128 * 64
    assert 0.2 < stats["ternary_sparsity"] < 0.6  # ~uniform → ~35% zeros
