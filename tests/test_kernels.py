"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracles in kernels/ref.py (interpret=True executes the Pallas body
on CPU; TPU is the deployment target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES_2D = [(128, 128), (256, 512), (64, 384), (100, 260)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_ternary_quantize_kernel(shape, dtype):
    theta = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    absw = jnp.abs(theta.astype(jnp.float32))
    mx = jnp.max(absw) + 1e-8
    inv = 1.0 / mx
    d = 0.7 * jnp.mean(absw) * inv
    sel = absw * inv > d
    wq = jnp.sum(jnp.where(sel, absw * inv, 0.0)) / (jnp.sum(sel) + 1e-8)

    it_k, tt_k = __import__("repro.kernels.ternary_quantize",
                            fromlist=["ternary_quantize"]).ternary_quantize(
        theta, inv, d, wq, interpret=True)
    it_r, tt_r = ref.ternary_quantize_ref(theta, inv, d, wq)
    np.testing.assert_array_equal(np.asarray(it_k), np.asarray(it_r))
    np.testing.assert_allclose(
        np.asarray(tt_k, np.float32), np.asarray(tt_r, np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


@pytest.mark.parametrize("k,n", [(128, 128), (512, 256), (1024, 130), (260, 64)])
def test_pack_unpack_kernel(k, n):
    key = jax.random.PRNGKey(1)
    it = jax.random.randint(key, (k, n), -1, 2).astype(jnp.int8)
    packed_k = ops.pack2bit(it, interpret=True)
    packed_r = ref.pack2bit_ref(it)
    np.testing.assert_array_equal(np.asarray(packed_k), np.asarray(packed_r))
    out = ops.unpack2bit(packed_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(it))


@pytest.mark.parametrize("m,k,n", [
    (128, 512, 256), (64, 128, 128), (8, 1024, 512), (100, 260, 130),
    (1, 512, 128),
])
@pytest.mark.parametrize("dtype", DTYPES)
def test_ternary_matmul_kernel(m, k, n, dtype):
    kk = (k // 4) * 4
    key = jax.random.PRNGKey(2)
    x = (jax.random.normal(key, (m, kk)) * 0.1).astype(dtype)
    it = jax.random.randint(jax.random.PRNGKey(3), (kk, n), -1, 2).astype(jnp.int8)
    packed = ref.pack2bit_ref(it)
    wq = jnp.asarray(0.037, jnp.float32)
    y_k = ops.ternary_matmul(x, packed, wq, interpret=True)
    y_r = ref.ternary_matmul_ref(x, packed, wq)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_fttq_apply_end_to_end():
    """ops.fttq_apply == core fttq math for one layer."""
    from repro.core import fttq as F

    theta = jax.random.normal(jax.random.PRNGKey(4), (256, 128))
    it, tt, wq = ops.fttq_apply(theta, 0.7, interpret=True)
    cfg = F.FTTQConfig()
    ts = F.scale_layer(theta)
    it_ref = F.ternarize(ts, F.fttq_threshold(ts, cfg.t_k))
    np.testing.assert_array_equal(np.asarray(it), np.asarray(it_ref, np.int8))
    # θ_t reconstructs in SCALED units: w_q(scaled) · I_t
    np.testing.assert_allclose(
        np.asarray(tt), np.asarray(float(wq) * np.asarray(it_ref)), rtol=1e-5
    )


def test_matmul_vs_dense_ref():
    """Packed kernel path == dense int8 reference contraction."""
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 256))
    it = jax.random.randint(jax.random.PRNGKey(6), (256, 64), -1, 2).astype(jnp.int8)
    wq = jnp.asarray(0.21, jnp.float32)
    y1 = ops.ternary_matmul(x, ref.pack2bit_ref(it), wq, interpret=True)
    y2 = ref.ternary_matmul_dense_ref(x, it, wq)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
