"""Distribution-layer tests: sharding rules, compressed collectives, and the
multi-pod trainer — run in a subprocess with 8 forced host devices so the
rest of the suite keeps the real single-device view."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Partial-auto shard_map (manual over "pod", auto over "data"/"model") hits
# a fatal CHECK in the XLA SPMD partitioner bundled with jax 0.4.x
# ("Check failed: sharding.IsManualSubgroup()" — the subprocess dies with
# SIGABRT before producing a result). jax ≥ 0.5 (which exports
# jax.shard_map at top level) ships the fixed partitioner. Full-manual
# shard_map (test_moe_a2a, fanin) is unaffected.
PARTIAL_AUTO_XFAIL = pytest.mark.xfail(
    condition=not hasattr(jax, "shard_map"),
    reason="partial-auto shard_map aborts XLA's SPMD partitioner on "
           "jax 0.4.x (IsManualSubgroup CHECK); needs jax ≥ 0.5",
)


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_specs_cover_tree():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    import repro.configs as C
    from repro.parallel.sharding import param_specs
    from repro.models.transformer import init_params
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)
        shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        specs = param_specs(cfg, mesh)
        assert (jax.tree_util.tree_structure(shapes)
                == jax.tree_util.tree_structure(specs)), arch
        # every spec entry is valid for its shape
        for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_leaves(specs),
        ):
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for d, entry in enumerate(spec):
                if entry is None:
                    continue
                assert leaf.shape[d] % sizes[entry] == 0, (arch, path, spec)
    print("SPECS_OK")
    """
    assert "SPECS_OK" in run_with_devices(code)


@PARTIAL_AUTO_XFAIL
def test_ternary_allreduce_approximates_mean():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.parallel.collectives import ternary_allreduce
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))

    def f(x):
        out, _ = ternary_allreduce(x[0], "pod", residual=None)
        return out

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                            out_specs=P(), axis_names={"pod"},
                            check_vma=False))(x)
    true_mean = jnp.mean(x, axis=0)
    # ternary mean correlates with true mean (quantized, not exact)
    a = np.asarray(out).ravel(); b = np.asarray(true_mean).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.5, corr
    print("ALLREDUCE_OK")
    """
    assert "ALLREDUCE_OK" in run_with_devices(code)


@PARTIAL_AUTO_XFAIL
def test_multipod_compressed_training_converges():
    code = """
    import jax, jax.numpy as jnp
    from repro.compat import set_mesh
    from repro.models.transformer import ModelConfig
    from repro.train import TrainerConfig, make_train_step, init_train_state
    from repro.optim import adam
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      vocab_size=128, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    losses = {}
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 128)}
    for compressed in (False, True):
        tcfg = TrainerConfig(qat=True, pod_compression=compressed,
                             error_feedback=True)
        opt = adam(2e-3)
        state = init_train_state(cfg, tcfg, opt, jax.random.PRNGKey(0), n_pods=2)
        step = make_train_step(cfg, tcfg, opt, mesh)
        with set_mesh(mesh):
            js = jax.jit(step)
            tr = []
            for _ in range(6):
                state, m = js(state, batch)
                tr.append(float(m["loss"]))
        losses[compressed] = tr
    # both converge; compressed stays within 25% of exact after 6 steps
    assert losses[False][-1] < losses[False][0]
    assert losses[True][-1] < losses[True][0]
    assert losses[True][-1] < losses[False][-1] * 1.25
    print("MULTIPOD_OK", losses)
    """
    assert "MULTIPOD_OK" in run_with_devices(code)


def test_elastic_remesh_after_pod_loss():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import set_mesh
    from repro.models.transformer import ModelConfig
    from repro.optim import adam
    from repro.train import TrainerConfig, init_train_state, make_train_step
    from repro.train.fault import elastic_reshard
    from repro.parallel.sharding import param_shardings
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      vocab_size=128, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128)
    tcfg = TrainerConfig(qat=False, pod_compression=False)
    opt = adam(1e-3)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 128)}

    # train on the 2-"pod" mesh
    mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    state = init_train_state(cfg, tcfg, opt, jax.random.PRNGKey(0))
    with set_mesh(mesh2):
        step2 = jax.jit(make_train_step(cfg, tcfg, opt, mesh2))
        state, m2 = step2(state, batch)

    # "pod failure": rebuild a 1-pod (4-device) mesh, reshard the WHOLE
    # state (params + optimizer moments + scalars), continue
    mesh1 = jax.make_mesh((2, 2), ("data", "model"))
    shard1 = param_shardings(cfg, mesh1)
    host = jax.device_get(state)
    repl = NamedSharding(mesh1, P())
    import dataclasses
    state1 = dataclasses.replace(
        host,
        params=elastic_reshard(host.params, shard1),
        opt_state={"step": jax.device_put(host.opt_state["step"], repl),
                   "m": elastic_reshard(host.opt_state["m"], shard1),
                   "v": elastic_reshard(host.opt_state["v"], shard1)},
        step=jax.device_put(host.step, repl),
    )
    with set_mesh(mesh1):
        step1 = jax.jit(make_train_step(cfg, tcfg, opt, mesh1))
        state1, m1 = step1(state1, batch)
    assert np.isfinite(float(m1["loss"]))
    print("ELASTIC_OK", float(m2["loss"]), float(m1["loss"]))
    """
    assert "ELASTIC_OK" in run_with_devices(code)
