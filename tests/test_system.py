"""End-to-end behaviour tests: the paper's headline claims on synthetic data
plus a reduced-mesh dry-run integration check (8 host devices, subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_tfedavg_matches_fedavg_accuracy_at_16x_less_comms():
    """Paper Tables II+IV in one: T-FedAvg reaches comparable accuracy to
    FedAvg with ~15× less measured communication."""
    from repro.data import partition_iid, synthetic_classification
    from repro.fed import FedConfig, run_federated
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist
    from repro.optim import adam

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 2000, 10, 784, noise=3.0, n_test=500
    )
    clients = partition_iid(x, y, 5)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        return float(acc), 0.0

    results = {}
    for algo in ("fedavg", "tfedavg"):
        cfg = FedConfig(algorithm=algo, participation=1.0, local_epochs=2,
                        batch_size=32, rounds=8)
        results[algo] = run_federated(mlp_mnist, params, clients, cfg,
                                      adam(1e-3), eval_fn, eval_every=8)
    acc_fp = results["fedavg"].accuracy[-1]
    acc_t = results["tfedavg"].accuracy[-1]
    ratio = results["fedavg"].upload_bytes / results["tfedavg"].upload_bytes
    assert acc_t > 0.85 * acc_fp, (acc_t, acc_fp)
    assert ratio > 10, ratio


def test_qat_lm_training_learns():
    """The paper's technique on a modern LM: FTTQ-QAT pretraining reduces
    loss on a synthetic token stream."""
    from repro.data.synthetic import synthetic_tokens, token_batches
    from repro.models.transformer import ModelConfig
    from repro.optim import adam
    from repro.train import TrainerConfig, init_train_state, make_train_step

    cfg = ModelConfig(name="lm", family="dense", n_layers=2, d_model=64,
                      vocab_size=64, n_heads=4, n_kv_heads=2, d_ff=128)
    tcfg = TrainerConfig(qat=True, pod_compression=False)
    opt = adam(3e-3)
    state = init_train_state(cfg, tcfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, opt))
    toks = synthetic_tokens(jax.random.PRNGKey(1), 30_000, vocab=64)
    it = token_batches(toks, batch=8, seq=32)
    losses = []
    for _ in range(30):
        batch, _ = next(it)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


@pytest.mark.slow
def test_reduced_mesh_dryrun_integration():
    """The dry-run machinery end-to-end on an 8-device mesh (subprocess)."""
    code = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro.configs as C
    from repro.compat import set_mesh
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.steps import make_decode_step
    from repro.models.transformer import init_params, init_cache
    from repro.parallel.sharding import batch_specs, param_specs

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = C.get_reduced("yi-9b", mesh_batch_axes=("data",),
                        param_dtype="bfloat16", compute_dtype="bfloat16")
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = param_specs(cfg, mesh)
    sh = lambda t, s: jax.tree_util.tree_map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)), t, s)
    params_sh = sh(params, pspecs)
    b, smax = 8, 64
    cache = jax.eval_shape(lambda: init_cache(cfg, b, smax, jnp.bfloat16))
    cache_sh = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
            sharding=NamedSharding(mesh, P(None, "data", None, None, None))), cache)
    batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32,
                 sharding=NamedSharding(mesh, P("data", None))),
             "cache": cache_sh,
             "pos": jax.ShapeDtypeStruct((), jnp.int32,
                 sharding=NamedSharding(mesh, P()))}
    step = make_decode_step(cfg)
    with set_mesh(mesh):
        compiled = jax.jit(step, donate_argnums=(1,)).lower(params_sh, batch).compile()
    ma = compiled.memory_analysis()
    r = analyze_hlo(compiled.as_text())
    assert r["flops_per_device"] > 0
    print("DRYRUN_OK", ma.temp_size_in_bytes, r["flops_per_device"])
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DRYRUN_OK" in out.stdout


def test_hlo_analyzer_against_xla_cost_analysis():
    """On a while-free program, the analyzer must agree with XLA's own
    FLOP count to within 5% (it counts dots; XLA adds elementwise)."""
    from repro.launch.hlo_analysis import analyze_hlo

    def f(w1, w2, x):
        return jnp.sum(jax.nn.gelu(x @ w1) @ w2)

    w1 = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    w2 = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    from repro.compat import cost_analysis

    comp = jax.jit(f).lower(w1, w2, x).compile()
    mine = analyze_hlo(comp.as_text())["flops_per_device"]
    xla = cost_analysis(comp)["flops"]
    assert abs(mine - xla) / xla < 0.05


def test_hlo_analyzer_scan_trip_counts():
    from repro.launch.hlo_analysis import analyze_hlo

    def scanned(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(x, w).compile()
    r = analyze_hlo(comp.as_text())
    assert r["flops_per_device"] == pytest.approx(12 * 2 * 64**3, rel=0.01)
    assert 12 in r["while_trip_counts"].values()


def test_paper_models_forward():
    from repro.models.paper_models import (
        init_mlp_mnist, init_resnet_cifar, mlp_mnist, resnet_cifar,
    )

    p = init_mlp_mnist(jax.random.PRNGKey(0))
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(p))
    assert n_params == 24330  # paper Table I
    out = mlp_mnist(p, jnp.ones((4, 784)))
    assert out.shape == (4, 10)

    rp = init_resnet_cifar(jax.random.PRNGKey(1))
    logits = resnet_cifar(rp, jnp.ones((2, 32, 32, 3)))
    assert logits.shape == (2, 10)
    assert not bool(jnp.any(jnp.isnan(logits)))
